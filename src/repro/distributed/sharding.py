"""Logical → physical sharding rules (DP / TP / EP / SP).

Parameters are matched by leaf-path suffix; every rule validates
divisibility against the mesh and falls back to replication when a dim
does not divide (e.g. phi3-medium's 10 KV heads on a 16-way model axis:
we shard head_dim instead — the "shard kv_heads if divisible, else
head_dim, else replicate" rule from DESIGN §5).

Activations get with_sharding_constraint via ``batch_spec`` helpers.
The same rule tree shards the optimizer moments (identical shapes).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(mesh: Mesh, dim: int, axes) -> bool:
    n = _axis_size(mesh, axes)
    return n > 1 and dim % n == 0


def data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# Each rule: (path regex, [axis-candidates per dim]).  Axis candidates are
# tried right-to-left per dim priority; None = replicate.  "DP" expands to
# the mesh's data axes, "MP" to the model axis.
_PARAM_RULES = [
    # embeddings / heads (vocab-parallel)
    (r"embed$",                 [("MP",), (None,)]),
    (r"lm_head$",               [(None,), ("MP",)]),
    # attention (stacked layer dim first when present)
    (r"(attn|xattn)/w[qkv]$",   [(None,), ("MP",)]),
    (r"(attn|xattn)/wo$",       [("MP",), (None,)]),
    (r"wq_a$",                  [(None,), (None,)]),
    (r"wq_b$",                  [(None,), ("MP",)]),
    (r"wkv_a$",                 [(None,), (None,)]),
    (r"wkv_b$",                 [(None,), ("MP",)]),
    # dense MLP (column-parallel up, row-parallel down)
    (r"mlp/w_(gate|up)$",       [(None,), ("MP",)]),
    (r"mlp/w_down$",            [("MP",), (None,)]),
    # MoE: experts over the model axis (EP)
    (r"moe/we_(gate|up|down)$", [("MP",), (None,), (None,)]),
    (r"moe/router$",            [(None,), (None,)]),
    (r"moe/ws_(gate|up)$",      [(None,), ("MP",)]),
    (r"moe/ws_down$",           [("MP",), (None,)]),
    # mamba2
    (r"ssm/in_(z|x|dt)$",       [(None,), ("MP",)]),
    (r"ssm/in_bc$",             [(None,), (None,)]),
    (r"ssm/conv_x_[wb]$",       [(None,), ("MP",)] ),
    (r"ssm/conv_bc_[wb]$",      [(None,), (None,)]),
    (r"ssm/out_proj$",          [("MP",), (None,)]),
    (r"ssm/(A_log|dt_bias|D)$", [("MP",)]),
    (r"ssm/norm$",              [("MP",)]),
    # griffin RG-LRU
    (r"rec/w_(gate_in|rec_in)$", [(None,), ("MP",)]),
    (r"rec/conv_[wb]$",         [(None,), ("MP",)]),
    (r"rec/w_[ri]$",            [(None,), ("MP",)]),
    (r"rec/(b_r|b_i|lam)$",     [("MP",)]),
    (r"rec/w_out$",             [("MP",), (None,)]),
    # MTP
    (r"mtp/proj$",              [(None,), ("MP",)]),
    (r"frontend_proj$",         [(None,), (None,)]),
]


def _spec_for_path(path: str, shape: tuple, mesh: Mesh) -> P:
    for pat, dim_rules in _PARAM_RULES:
        if re.search(pat, path):
            # stacked-layer / stacked-group leading dims are never sharded
            extra = len(shape) - len(dim_rules)
            spec = [None] * extra
            for dim, cands in zip(shape[extra:], dim_rules):
                chosen = None
                for cand in cands:
                    if cand is None:
                        continue
                    axes = ("model",) if cand == "MP" else data_axes(mesh)
                    if _fits(mesh, dim, axes):
                        chosen = axes[0] if len(axes) == 1 else axes
                        break
                spec.append(chosen)
            return P(*spec)
    return P()                                   # norms, scalars: replicate


def _fsdp_spec_for_path(path: str, shape: tuple, mesh: Mesh) -> P:
    """FSDP / ZeRO-3 sharding: every weight matrix shards one large dim over
    ALL axes ("data"+"model" ⇒ 256-way); XLA all-gathers the layer's weights
    just-in-time per use and reduce-scatters its gradients.  Activations run
    pure-DP (no TP collectives).  MoE keeps experts on "model" (EP) and
    shards d_model over the remaining axes (§Perf hillclimb #2)."""
    all_axes = tuple(mesh.axis_names)            # ("pod","data","model")…
    dp = data_axes(mesh)
    if re.search(r"moe/we_(gate|up|down)$", path):
        # E → model (EP); the *output* dim → data.  Sharding the contracting
        # dim instead makes GSPMD gather full expert activations (the same
        # failure mode as §Perf A1, measured again in B2: 4.4 TiB of expert
        # weight/activation gathers).
        spec = [None] * (len(shape) - 3)
        e, a, b = shape[-3:]
        s_e = "model" if _fits(mesh, e, ("model",)) else None
        s_b = (dp if len(dp) > 1 else dp[0]) if _fits(mesh, b, dp) else None
        spec += [s_e, None, s_b]
        return P(*spec)
    if len(shape) == 0:
        return P()
    # Stacked-layer leading dim stays unsharded.  Prefer the LAST (output)
    # dim: sharding a matmul's contracting dim makes GSPMD compute weight
    # grads by all-gathering full-batch fp32 activations (measured: 16 GiB
    # per layer per traversal — §Perf iteration 1, refuted hypothesis).
    # Output-dim sharding keeps grads local + reduce-scattered.
    lead = 1 if len(shape) >= 3 else 0
    dims = list(range(lead, len(shape)))
    if not dims:
        return P()
    for axes in (all_axes, dp, ("model",)):
        for d in sorted(dims, key=lambda i: -i):
            if _fits(mesh, shape[d], axes):
                spec = [None] * len(shape)
                spec[d] = axes if len(axes) > 1 else axes[0]
                return P(*spec)
    return P()


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_specs(params_tree, mesh: Mesh, mode: str = "tp"):
    """PartitionSpec pytree for a parameter (or abstract-shape) pytree.
    mode: "tp" (Megatron tensor parallel, baseline) | "fsdp" (ZeRO-3)."""
    fn = _fsdp_spec_for_path if mode == "fsdp" else _spec_for_path
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: fn(_path_str(path), leaf.shape, mesh),
        params_tree)


def opt_specs(opt_tree, param_spec_tree):
    """Optimizer moments shard like their parameters."""
    return {
        "m": param_spec_tree,
        "v": param_spec_tree,
        "step": P(),
    }


# ------------------------------------------------------------- activations
def batch_spec(mesh: Mesh, shape: tuple, batch_dim: int = 0,
               extra: Optional[dict] = None, mode: str = "tp") -> P:
    """Shard dim ``batch_dim`` over the data axes (tp) or ALL axes (fsdp:
    pure-DP compute, every chip gets its own batch slice)."""
    dp = tuple(mesh.axis_names) if mode == "fsdp" else data_axes(mesh)
    spec = [None] * len(shape)
    if _fits(mesh, shape[batch_dim], dp):
        spec[batch_dim] = dp if len(dp) > 1 else dp[0]
    elif mode == "fsdp" and _fits(mesh, shape[batch_dim], data_axes(mesh)):
        d2 = data_axes(mesh)
        spec[batch_dim] = d2 if len(d2) > 1 else d2[0]
    if extra:
        for d, axes in extra.items():
            if _fits(mesh, shape[d], axes):
                spec[d] = axes if isinstance(axes, str) else \
                    (axes if len(axes) > 1 else axes[0])
    return P(*spec)


def kv_head_axis_dims(kv_heads: int, entry_dim: int, mesh: Mesh):
    """DESIGN §5 rule: shard kv_heads over model if divisible, else the
    packed entry dim, else replicate.  Returns (kv_spec_axis, entry_axis)."""
    if _fits(mesh, kv_heads, ("model",)):
        return "model", None
    if _fits(mesh, entry_dim, ("model",)):
        return None, "model"
    return None, None


def cache_specs_tree(cache_tree, mesh: Mesh):
    """PartitionSpecs for a KV-WAL / state cache pytree (by leaf name)."""
    dp = data_axes(mesh)

    def spec(path, leaf):
        name = _path_str(path)
        sh = leaf.shape
        if ("arena_k" in name or "arena_v" in name) and len(sh) >= 5:
            # (L?, B, nb, blk, KH, dim)
            off = len(sh) - 5                    # tail arenas have no L dim
            s = [None] * len(sh)
            if _fits(mesh, sh[off], dp):
                s[off] = dp if len(dp) > 1 else dp[0]
            kh_ax, ed_ax = kv_head_axis_dims(sh[off + 3], sh[off + 4], mesh)
            s[off + 3] = kh_ax
            s[off + 4] = ed_ax
            return P(*s)
        if name.endswith(("cross_k", "cross_v")) and len(sh) == 5:
            s = [None, None, None, None, None]
            if _fits(mesh, sh[1], dp):
                s[1] = dp if len(dp) > 1 else dp[0]
            kh_ax, ed_ax = kv_head_axis_dims(sh[3], sh[4], mesh)
            s[3], s[4] = kh_ax, ed_ax
            return P(*s)
        if name.endswith("state") and len(sh) == 5:   # ssm (L,B,h,p,n)
            s = [None] * 5
            if _fits(mesh, sh[1], dp):
                s[1] = dp if len(dp) > 1 else dp[0]
            if _fits(mesh, sh[2], ("model",)):
                s[2] = "model"
            return P(*s)
        if ("conv" in name or "lru" in name) and len(sh) >= 3:
            s = [None] * len(sh)
            bdim = len(sh) - 3 if "conv" in name else len(sh) - 2
            if _fits(mesh, sh[bdim], dp):
                s[bdim] = dp if len(dp) > 1 else dp[0]
            if _fits(mesh, sh[-1], ("model",)):
                s[-1] = "model"
            return P(*s)
        if name.endswith(("seq_lens", "first_live", "table")):
            return P()
        # fallback: shard the most plausible batch dim
        return batch_spec(mesh, sh, 0 if len(sh) <= 2 else 1)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def input_specs_tree(specs: dict, mesh: Mesh, mode: str = "tp"):
    """Shardings for dry-run/step inputs keyed by input name."""
    out = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = cache_specs_tree(v, mesh)
        elif k == "mrope_positions":
            out[k] = batch_spec(mesh, v.shape, batch_dim=1, mode=mode)
        else:
            out[k] = batch_spec(mesh, v.shape, batch_dim=0, mode=mode)
    return out


def named(tree_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
