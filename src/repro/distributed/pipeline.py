"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Layers are partitioned into S contiguous stages along a mesh axis; a batch
is split into M microbatches that flow through the stages in a T = M+S−1
tick schedule.  Each tick every stage applies its local layer block and
forwards its activation to the next stage with a ring ppermute — the
classic GPipe bubble of (S−1)/T idle ticks.

This is an optional execution mode (off in baseline dry-runs): pipelining
trades the TP/FSDP collective volume for point-to-point transfers of one
(microbatch × d_model) activation per tick, which matters once a model's
layer count × size outgrows what DP+TP can hold per chip.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(layer_fn: Callable, stacked_params, x, *,
                     mesh: Mesh, stage_axis: str = "model",
                     n_microbatches: int = 4):
    """Run ``x`` through all layers, pipelined over ``stage_axis``.

    layer_fn(layer_params, x) → x, applied once per layer.
    stacked_params: pytree with leading layer dim L (L % n_stages == 0).
    x: (B, ...) with B % n_microbatches == 0.
    """
    n_stages = mesh.shape[stage_axis]
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    assert L % n_stages == 0, (L, n_stages)
    B = x.shape[0]
    assert B % n_microbatches == 0
    M = n_microbatches
    mb = B // M

    def local_block(params_local, h):
        # apply this stage's layers sequentially
        def body(carry, layer_p):
            return layer_fn(layer_p, carry), None
        out, _ = jax.lax.scan(body, h, params_local)
        return out

    def stage_fn(params_local, x_local):
        # x_local: full batch (replicated along the stage axis)
        stage = jax.lax.axis_index(stage_axis)
        micro = x_local.reshape((M, mb) + x_local.shape[1:])
        T = M + n_stages - 1
        buf = jnp.zeros((mb,) + x_local.shape[1:], x_local.dtype)
        out = jnp.zeros_like(micro)

        def tick(carry, t):
            buf, out = carry
            inject = micro[jnp.minimum(t, M - 1)]
            h = jnp.where(stage == 0,
                          jnp.where(t < M, 1.0, 0.0) * inject, buf)
            h = local_block(params_local, h)
            # forward to the next stage (ring; last stage's send unused)
            nxt = jax.lax.ppermute(
                h, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            slot = t - (n_stages - 1)
            is_out = (stage == n_stages - 1) & (slot >= 0)
            out = jnp.where(
                is_out,
                out.at[jnp.clip(slot, 0, M - 1)].set(h),
                out)
            return (nxt, out), None

        (_, out), _ = jax.lax.scan(tick, (buf, out), jnp.arange(T))
        # only the last stage holds real outputs; broadcast via masked psum
        result = out.reshape(x_local.shape)
        result = jax.lax.psum(
            jnp.where(stage == n_stages - 1, result, 0), stage_axis)
        return result

    fn = shard_map(
        stage_fn, mesh=mesh,
        in_specs=(P(stage_axis), P()),     # params split by stage; x replic.
        out_specs=P(),
        check_rep=False,
    )
    return fn(stacked_params, x)
