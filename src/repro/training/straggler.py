"""Straggler detection and mitigation.

At 1000+ nodes, slow hosts (thermal throttling, failing NICs, noisy
neighbours) stall synchronous training.  The monitor keeps an EMA of step
times; a step exceeding ``threshold × EMA`` is flagged, repeated offenders
trigger the configured action: log, checkpoint-and-raise (so the cluster
scheduler replaces the host and the run auto-resumes), or callback.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional


class StragglerAbort(RuntimeError):
    """Raised to hand control back to the restart wrapper."""


@dataclass
class StragglerMonitor:
    threshold: float = 3.0          # step slower than 3× EMA ⇒ suspect
    ema_alpha: float = 0.1
    patience: int = 3               # consecutive slow steps before action
    action: str = "log"             # "log" | "abort" | "callback"
    deadline_s: Optional[float] = None   # hard per-step ceiling
    on_straggler: Optional[Callable[[int, float, float], None]] = None
    ema: Optional[float] = field(default=None, init=False)
    slow_streak: int = field(default=0, init=False)
    events: list = field(default_factory=list, init=False)
    _t0: Optional[float] = field(default=None, init=False)

    def step_start(self) -> None:
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> float:
        dt = time.monotonic() - self._t0
        if self.ema is None:
            self.ema = dt
            return dt
        slow = dt > self.threshold * self.ema or (
            self.deadline_s is not None and dt > self.deadline_s)
        if slow:
            self.slow_streak += 1
            self.events.append((step, dt, self.ema))
            if self.on_straggler:
                self.on_straggler(step, dt, self.ema)
            if self.slow_streak >= self.patience:
                if self.action == "abort":
                    raise StragglerAbort(
                        f"step {step}: {dt:.3f}s vs EMA {self.ema:.3f}s "
                        f"({self.slow_streak} consecutive slow steps)")
        else:
            self.slow_streak = 0
            # only healthy steps update the EMA (a straggler must not
            # poison the baseline)
            self.ema = (1 - self.ema_alpha) * self.ema + self.ema_alpha * dt
        return dt
