"""Restartable training loop: auto-resume from the tidestore checkpoint WAL,
straggler watchdog, optional failure injection (tests/chaos engineering).

``run`` is written so that a crash at ANY point (including mid-checkpoint —
the WAL's batch atomicity guarantees a manifest is either fully visible or
absent) resumes from the last durable step.  Restarting with a different
mesh works because checkpoint values are topology-agnostic (elastic
scaling): the restore path re-sharding is exercised in
tests/test_training.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import numpy as np

from repro.core.checkpoint import CheckpointManager
from repro.models import transformer as T
from repro.models.base import ModelConfig

from .optimizer import AdamWConfig, adamw_init
from .step import make_train_step
from .straggler import StragglerAbort, StragglerMonitor


@dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    log_every: int = 10
    seed: int = 0
    fail_at_step: Optional[int] = None    # failure injection (tests)
    straggler_action: str = "log"


def run(cfg: ModelConfig, opt: AdamWConfig, loop: LoopConfig,
        batch_fn: Callable[[int], dict], ckpt_dir: str,
        jit_step=None, shardings=None,
        log_fn: Callable[[str], None] = print) -> dict:
    """Train with auto-resume.  Returns summary metrics."""
    ckpt = CheckpointManager(ckpt_dir)
    params = T.init_params(cfg, jax.random.PRNGKey(loop.seed))
    opt_state = adamw_init(params, opt)
    state = {"params": params, "opt": opt_state}

    restored, step0 = ckpt.restore(
        jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
        shardings=shardings)
    if restored is not None:
        state = restored
        start_step = step0 + 1
        log_fn(f"[loop] resumed from step {step0}")
    else:
        start_step = 0

    step_fn = jit_step if jit_step is not None else jax.jit(
        make_train_step(cfg, opt), donate_argnums=(0, 1))
    monitor = StragglerMonitor(action=loop.straggler_action)
    losses = []
    try:
        for step in range(start_step, loop.total_steps):
            monitor.step_start()
            batch = batch_fn(step)
            params, opt_state, metrics = step_fn(state["params"],
                                                 state["opt"], batch)
            state = {"params": params, "opt": opt_state}
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = monitor.step_end(step)
            if step % loop.log_every == 0:
                log_fn(f"[loop] step {step} loss {loss:.4f} "
                       f"({dt*1e3:.0f} ms)")
            if loop.fail_at_step is not None and step == loop.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            if step % loop.checkpoint_every == 0 or \
                    step == loop.total_steps - 1:
                ckpt.save(step, state)
    finally:
        ckpt.close()
    return {"final_loss": losses[-1] if losses else float("nan"),
            "losses": losses, "last_step": loop.total_steps - 1,
            "straggler_events": list(monitor.events),
            "resumed_from": step0 if restored is not None else None}
