"""Train / prefill / decode step factories used by the launcher and dry-run."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import serve as serve_mod
from repro.models import transformer as T
from repro.models.base import ModelConfig

from .optimizer import AdamWConfig, adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, opt: AdamWConfig,
                    compress_grads=None):
    """Returns train_step(params, opt_state, batch) → (params, opt_state,
    metrics).  ``compress_grads`` optionally transforms the gradient pytree
    (e.g. int8 quantize→psum→dequantize, distributed/compression.py)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(T.train_loss)(params, cfg, batch)
        if compress_grads is not None:
            grads = compress_grads(grads)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state, opt)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill_step(cfg: ModelConfig, max_seq: int):
    def prefill_step(params, batch):
        return serve_mod.prefill(params, cfg, batch, max_seq=max_seq)
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def step(params, cache, tokens, mrope_positions=None):
        return serve_mod.decode_step(params, cfg, cache, tokens,
                                     mrope_positions=mrope_positions)
    return step


def init_train_state(cfg: ModelConfig, opt: AdamWConfig, key):
    params = T.init_params(cfg, key)
    return params, adamw_init(params, opt)


def abstract_train_state(cfg: ModelConfig, opt: AdamWConfig):
    """ShapeDtypeStructs for params + optimizer state (no allocation)."""
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    params = jax.eval_shape(lambda k: T.init_params(cfg, k), key)
    opt_state = jax.eval_shape(lambda p: adamw_init(p, opt), params)
    return params, opt_state
