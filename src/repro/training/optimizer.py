"""AdamW in pure JAX (no optax dependency).

Moments shard exactly like their parameters (the sharding rules apply to the
whole train-state pytree), so optimizer memory scales down with the mesh.
``moment_dtype`` lets memory-constrained configs (deepseek-v3 on one pod)
drop to bf16 moments — recorded as a deviation in the roofline notes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100


def adamw_init(params, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, grad_norm)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    lr = _schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        update = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if p.ndim >= 2:                       # decoupled weight decay
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * update
        return newp.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
