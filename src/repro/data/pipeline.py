"""Data pipeline: deterministic synthetic token shards + a content-addressed
sample store backed by the Tidehunter engine.

The dedup store is the paper's content-addressable workload (§1: "keys lack
locality by design"): samples are keyed by blake2b of their token bytes, so
re-ingesting a shard writes nothing new, and epoch-expired shards are
reclaimed at WAL-segment granularity.
"""
from __future__ import annotations

import hashlib
from typing import Iterator, Optional

import numpy as np

from repro.core.tidestore import DbConfig, KeyspaceConfig, TideDB
from repro.core.tidestore.wal import WalConfig


def synthetic_batch(step: int, batch: int, seq: int, vocab: int,
                    seed: int = 0) -> dict:
    """Deterministic per-step batch (restart-safe: same step ⇒ same data)."""
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + step))
    tokens = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class ContentAddressedStore:
    """Dedup sample store: put-if-absent by content hash."""

    def __init__(self, path: str, background: bool = True):
        cfg = DbConfig(
            keyspaces=[KeyspaceConfig("samples", n_cells=128,
                                      dirty_flush_threshold=1024)],
            wal=WalConfig(segment_size=16 * 1024 * 1024,
                          background=background),
            index_wal=WalConfig(segment_size=8 * 1024 * 1024,
                                background=background),
            background_snapshots=background,
        )
        self.db = TideDB(path, cfg)
        self.dedup_hits = 0
        self.inserted = 0

    @staticmethod
    def key_of(sample: bytes) -> bytes:
        return hashlib.blake2b(sample, digest_size=32).digest()

    def put(self, sample: bytes, epoch: int = 0) -> bytes:
        key = self.key_of(sample)
        if self.db.exists(key, keyspace="samples"):
            self.dedup_hits += 1          # bloom+index, no value fetched
            return key
        self.db.put(key, sample, keyspace="samples", epoch=epoch)
        self.inserted += 1
        return key

    def get(self, key: bytes) -> Optional[bytes]:
        return self.db.get(key, keyspace="samples")

    def ingest_tokens(self, tokens: np.ndarray, epoch: int = 0) -> list[bytes]:
        return [self.put(np.ascontiguousarray(row).tobytes(), epoch)
                for row in tokens]

    def expire_epochs_below(self, epoch: int) -> int:
        return self.db.prune_epochs_below(epoch)

    def close(self) -> None:
        self.db.close()
