"""Production mesh construction.

Target hardware: TPU v5e pods — 16×16 = 256 chips per pod; multi-pod adds a
leading "pod" axis (data parallel across DCN).  Defined as functions so that
importing this module never touches jax device state (the dry-run must set
XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_abstract_mesh(shape, axis_names):
    """Device-free AbstractMesh across JAX versions.

    JAX 0.4.x takes a single ``((name, size), ...)`` shape tuple; newer
    releases take ``(axis_sizes, axis_names)``.  Centralized here so the
    next JAX bump is a one-line fix instead of a test-suite sweep.
    """
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axis_names, shape)))
    except TypeError:
        return AbstractMesh(tuple(shape), tuple(axis_names))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over however many (real or forced) host devices exist —
    used by tests and the CPU examples."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Axes that shard the batch: ('pod','data') on multi-pod meshes."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"
