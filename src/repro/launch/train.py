"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires a registry config (full or smoke), the mesh, sharded step functions,
tidestore checkpointing and the restartable loop.  On this CPU container
use ``--smoke`` (full configs need the pod).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import synthetic_batch
from repro.training.loop import LoopConfig, run
from repro.training.optimizer import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro-train")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    opt = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1))

    def batch_fn(step):
        b = synthetic_batch(step, args.batch, args.seq, cfg.vocab)
        out = {k: jnp.asarray(v) for k, v in b.items()}
        if cfg.family == "vlm":
            out["vision_embed"] = jnp.zeros((args.batch, 4, cfg.d_model),
                                            cfg.adtype)
            pos = jnp.broadcast_to(jnp.arange(args.seq)[None],
                                   (args.batch, args.seq))
            out["mrope_positions"] = jnp.broadcast_to(pos[None], (3,) + pos.shape)
        if cfg.family == "encdec":
            out["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.encoder_dim), cfg.adtype)
        return out

    summary = run(cfg, opt,
                  LoopConfig(total_steps=args.steps,
                             checkpoint_every=args.checkpoint_every),
                  batch_fn, args.ckpt_dir)
    print(f"[train] {args.arch}: loss {summary['losses'][0]:.4f} → "
          f"{summary['final_loss']:.4f} over {args.steps} steps "
          f"(resumed_from={summary['resumed_from']})")


if __name__ == "__main__":
    main()
