"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Runs the continuous-batching engine over the Tidehunter KV-WAL with a
synthetic request stream; reports throughput, latency and segment-recycling
stats.  Use ``--smoke`` on CPU.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import transformer as T
from repro.serving.engine import ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family not in ("dense", "vlm", "moe"):
        raise SystemExit(f"{args.arch}: the serving engine drives "
                         f"KV-WAL-cache families (dense/vlm/moe)")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, batch_slots=args.slots,
                           max_seq=args.max_seq)
    rng = np.random.default_rng(0)
    reqs = [engine.submit(rng.integers(0, cfg.vocab, 1 + i % 5),
                          max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]
    t0 = time.time()
    while engine.queue or engine.active:
        engine.step()
    dt = time.time() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"[serve] {args.arch}: {len(reqs)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s, segments recycled="
          f"{engine.segments_recycled}")


if __name__ == "__main__":
    main()
