import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import: jax locks the device
# count at first initialization, and the production meshes need 512
# placeholder devices (2 pods × 16 × 16).

import argparse                                    # noqa: E402
import dataclasses                                 # noqa: E402
import json                                        # noqa: E402
import time                                        # noqa: E402
import traceback                                   # noqa: E402

import jax                                         # noqa: E402
import jax.numpy as jnp                            # noqa: E402
import numpy as np                                 # noqa: E402

from repro.configs.registry import (ARCH_IDS, SHAPES, get_config,  # noqa: E402
                                    input_specs, runnable)
from repro.distributed import sharding             # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline import analysis as roofline    # noqa: E402
from repro.roofline import hw                      # noqa: E402
from repro.roofline.jaxpr_cost import jaxpr_cost   # noqa: E402
from repro.training.optimizer import AdamWConfig   # noqa: E402
from repro.training.step import (abstract_train_state,  # noqa: E402
                                 make_decode_step, make_prefill_step,
                                 make_train_step)

# Memory-constrained giants drop to bf16 optimizer moments (DESIGN §9).
_BF16_MOMENTS = {"deepseek-v3-671b", "qwen2-vl-72b"}


def chips_of(multi_pod: bool) -> int:
    return 512 if multi_pod else 256


def _opt_for(arch: str) -> AdamWConfig:
    return AdamWConfig(
        moment_dtype="bfloat16" if arch in _BF16_MOMENTS else "float32")


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None, sharding_mode: str = "tp"):
    """Lower + compile one (arch × shape × mesh) cell.  Returns artifacts."""
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if shape.kind != "train":
        # Serving uses bf16 weights.
        cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    if shape.kind == "prefill" or (shape.kind == "train"
                                   and shape.seq_len > 8192):
        cfg = dataclasses.replace(cfg, attn_chunk_q=1024)
    for k, v in (overrides or {}).items():
        if isinstance(v, list):
            v = tuple(v)
        cfg = dataclasses.replace(cfg, **{k: v})

    mesh = make_production_mesh(multi_pod=multi_pod)
    specs = input_specs(cfg, shape)
    in_specs = sharding.named(
        sharding.input_specs_tree(specs, mesh, mode=sharding_mode), mesh)
    opt = _opt_for(arch)
    params_abs, opt_abs = abstract_train_state(cfg, opt)
    pspec = sharding.named(
        sharding.param_specs(params_abs, mesh, mode=sharding_mode), mesh)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            step = make_train_step(cfg, opt)
            ospec = {"m": pspec, "v": pspec,
                     "step": sharding.named(
                         jax.sharding.PartitionSpec(), mesh)}
            lowered = jax.jit(
                step,
                in_shardings=(pspec, ospec, in_specs),
                out_shardings=(pspec, ospec, None),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, max_seq=shape.seq_len + 256)
            from repro.models import serve as serve_mod
            cache_abs = serve_mod.cache_spec(cfg, specs["tokens"].shape[0],
                                             shape.seq_len + 256)
            cspec = sharding.named(sharding.cache_specs_tree(cache_abs, mesh),
                                   mesh)
            lowered = jax.jit(
                step, in_shardings=(pspec, in_specs),
                out_shardings=(None, cspec),
            ).lower(params_abs, specs)
        else:  # decode
            step = make_decode_step(cfg)
            cspec = in_specs["cache"]
            args = [params_abs, specs["cache"], specs["tokens"]]
            in_sh = [pspec, cspec, in_specs["tokens"]]
            kwargs = {}
            if "mrope_positions" in specs:
                args.append(specs["mrope_positions"])
                in_sh.append(in_specs["mrope_positions"])
            lowered = jax.jit(
                step, in_shardings=tuple(in_sh),
                out_shardings=(None, cspec), donate_argnums=(1,),
            ).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                mem[k] = int(getattr(ma, k))
    except Exception as e:                         # pragma: no cover
        mem["error"] = str(e)

    cost = compiled.cost_analysis() or {}
    # XLA's cost_analysis visits while bodies once (layer scans undercounted
    # ~n_layers×); the jaxpr walker recurses with trip counts — see
    # roofline/jaxpr_cost.py.  Counts are global; divide by chips.
    if shape.kind == "train":
        jc = jaxpr_cost(step, params_abs, opt_abs, specs)
    elif shape.kind == "prefill":
        jc = jaxpr_cost(step, params_abs, specs)
    else:
        jc = jaxpr_cost(step, *args)
    flops = jc.flops / chips_of(multi_pod)
    bytes_acc = jc.bytes / chips_of(multi_pod)
    hlo = compiled.as_text()
    coll = roofline.parse_collectives(hlo)

    n_tokens = shape.seq_len * shape.global_batch if shape.kind != "decode" \
        else shape.global_batch
    mf = roofline.model_flops(cfg, n_tokens, shape.kind)
    chips = 512 if multi_pod else 256
    rf = roofline.Roofline(
        arch=arch, shape=shape_name,
        mesh="2x16x16" if multi_pod else "16x16", chips=chips,
        flops_per_device=flops, bytes_per_device=bytes_acc,
        collective_bytes=float(coll.total_bytes),
        peak_memory_per_device=float(
            mem.get("argument_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0)
            - mem.get("alias_size_in_bytes", 0)),
        model_flops=mf,
        collectives={"bytes": coll.bytes_by_kind,
                     "count": coll.count_by_kind},
    )
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory_analysis": mem,
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "roofline": rf.to_dict(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description="Multi-pod dry-run")
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides as key=value (perf experiments)")
    ap.add_argument("--sharding-mode", default="tp", choices=["tp", "fsdp"])
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    def record(entry):
        results[:] = [r for r in results
                      if not (r["arch"] == entry["arch"]
                              and r["shape"] == entry["shape"]
                              and r["mesh"] == entry["mesh"])]
        results.append(entry)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    for arch in archs:
        for shape_name in shapes:
            ok, reason = runnable(arch, shape_name)
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                tag = f"{arch} × {shape_name} × {mesh_name}"
                if not ok:
                    print(f"[dryrun] {tag}: {reason}")
                    record({"arch": arch, "shape": shape_name,
                            "mesh": mesh_name, "status": reason})
                    continue
                try:
                    t0 = time.time()
                    entry = lower_cell(arch, shape_name, multi, overrides,
                                       sharding_mode=args.sharding_mode)
                    rf = entry["roofline"]
                    print(f"[dryrun] {tag}: OK in {time.time()-t0:.0f}s — "
                          f"flops/dev={rf['flops_per_device']:.3e} "
                          f"coll={rf['collective_bytes']:.3e}B "
                          f"bottleneck={rf['bottleneck']} "
                          f"mem/dev={rf['peak_memory_per_device']/2**30:.2f}GiB")
                    record(entry)
                except Exception as e:
                    traceback.print_exc()
                    print(f"[dryrun] {tag}: FAIL {e}")
                    record({"arch": arch, "shape": shape_name,
                            "mesh": mesh_name, "status": f"FAIL: {e}"})
    n_ok = sum(1 for r in results if r.get("status") == "ok")
    print(f"[dryrun] {n_ok}/{len(results)} cells OK → {args.out}")


if __name__ == "__main__":
    main()
