"""Shared harness: engine constructors + workload generators.

Scaled-down reproduction of the paper's methodology (§6.1): fill phase with
random 32-byte keys and fixed-size values, then a timed measurement phase.
Absolute ops/s on 1 CPU core are not comparable to the paper's 48-thread
NVMe box; the *ratios* between engines and the *shapes* of the curves are
the reproduction targets (DESIGN §9).
"""
from __future__ import annotations

import hashlib
import shutil
import tempfile
import time

import numpy as np

from repro.core.lsm_baseline import LsmBaseline, LsmConfig
from repro.core.tidestore import (DbConfig, KeyspaceConfig, ShardedTideDB,
                                  TideDB)
from repro.core.tidestore.wal import WalConfig


def _tide_cfg(relocation=False, copy_threads=None):
    cfg = DbConfig(
        keyspaces=[KeyspaceConfig("default", n_cells=256,
                                  dirty_flush_threshold=2048)],
        wal=WalConfig(segment_size=8 * 1024 * 1024),
        index_wal=WalConfig(segment_size=32 * 1024 * 1024),
        relocation=relocation,
        cache_bytes=8 * 1024 * 1024,
    )
    if copy_threads is not None:
        cfg.copy_threads = copy_threads
    return cfg


def make_tide(path, relocation=False, copy_threads=None):
    return TideDB(path, _tide_cfg(relocation, copy_threads=copy_threads))


def make_tide_sharded(path, n_shards=4):
    """Static key-space sharding: N independent TideDB shards behind the
    Engine protocol, batched reads fanned across a thread pool."""
    return ShardedTideDB(path, _tide_cfg(), n_shards=n_shards)


def make_rocks(path):
    """RocksDB stand-in: leveled LSM with compaction.  The memtable is kept
    small relative to the scaled dataset so flushes + compactions actually
    run (at the paper's 1 TB scale the memtable is likewise ≪ dataset)."""
    return LsmBaseline(path, LsmConfig(memtable_entries=512))


def make_blob(path):
    """BlobDB/WiscKey stand-in: key-value separated LSM."""
    return LsmBaseline(path, LsmConfig(memtable_entries=512,
                                       blob_mode=True))


ENGINES = {"tidehunter": make_tide, "rocksdb(sim)": make_rocks,
           "blobdb(sim)": make_blob}


def gen_keys(n: int, seed: int = 0) -> list[bytes]:
    return [hashlib.sha256(f"{seed}:{i}".encode()).digest()
            for i in range(n)]


def zipf_indices(n_keys: int, n_ops: int, theta: float,
                 seed: int = 1) -> np.ndarray:
    """theta=0 → homogeneous uniform; theta=2 → heavily recent-skewed
    (paper §6.1: skew favors recently inserted keys)."""
    rng = np.random.default_rng(seed)
    if theta == 0:
        return rng.integers(0, n_keys, n_ops)
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    w = ranks ** -theta
    w /= w.sum()
    # rank 1 = most recently inserted
    return n_keys - 1 - rng.choice(n_keys, size=n_ops, p=w)


def multi_get(db, keys):
    """Batched get where the engine supports it; scalar loop otherwise —
    the exact baseline the batched pipeline is measured against."""
    fn = getattr(db, "multi_get", None)
    if fn is not None:
        return fn(keys)
    return [db.get(k) for k in keys]


def multi_exists(db, keys):
    fn = getattr(db, "multi_exists", None)
    if fn is not None:
        return fn(keys)
    return [db.exists(k) for k in keys]


class Bench:
    def __init__(self, name: str, factory):
        self.name = name
        self.dir = tempfile.mkdtemp(prefix=f"bench-{name.split('(')[0]}-")
        self.db = factory(self.dir)

    def fill(self, keys, value_size: int):
        v = bytes(value_size)
        t0 = time.perf_counter()
        for k in keys:
            self.db.put(k, v)
        if hasattr(self.db, "flush"):
            self.db.flush()
        return time.perf_counter() - t0

    def close(self):
        self.db.close()
        shutil.rmtree(self.dir, ignore_errors=True)


def timed_ops(fn, ops) -> tuple[float, int]:
    t0 = time.perf_counter()
    n = 0
    for op in ops:
        fn(op)
        n += 1
    return time.perf_counter() - t0, n
