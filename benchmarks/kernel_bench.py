"""Kernel microbenchmarks (interpret mode on CPU — structural metrics).

Wall-clock timings of interpret-mode Pallas are NOT TPU timings; the
meaningful numbers reported here are the *structural* ones that transfer:
bytes staged into VMEM per lookup as a function of window size (the Fig 10
trade-off), iteration counts, and oracle-vs-kernel agreement rates.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.optimistic_lookup.kernel import optimistic_lookup
from repro.kernels.tide_attention.kernel import tide_attention
from repro.kernels.tide_attention.ref import tide_attention_ref


def run(csv=print) -> None:
    rng = np.random.default_rng(5)
    # --- optimistic_lookup window sweep (device analogue of Fig 10) ---
    N, Q = 100_000, 512
    keys = np.unique(rng.integers(0, 2**32, N, dtype=np.uint32))
    queries = jnp.asarray(rng.integers(0, 2**32, Q, dtype=np.uint32))
    kj = jnp.asarray(keys)
    for w in (128, 256, 512, 1024, 2048):
        idx, found, iters = jax.block_until_ready(
            optimistic_lookup(queries, kj, window=w, interpret=True))
        it = np.asarray(iters)
        resolved = (np.asarray(idx) >= 0).mean()
        bytes_per_lookup = int(it.mean() * w * 4)
        csv(f"kernel.optimistic.w{w},{it.mean():.3f},"
            f"iters/lookup bytes_staged={bytes_per_lookup} "
            f"resolved={resolved:.3f}")

    # --- tide_attention: kernel vs ref agreement + HBM-traffic model ---
    B, H, KH, dk, NB, blk = 4, 8, 4, 128, 16, 128
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, dk), jnp.float32)
    ak = jax.random.normal(key, (B, NB, blk, KH, dk), jnp.float32)
    av = jax.random.normal(key, (B, NB, blk, KH, dk), jnp.float32)
    table = jnp.broadcast_to(jnp.arange(NB, dtype=jnp.int32), (B, NB))
    lens = jnp.full((B,), NB * blk, jnp.int32)
    live = jnp.zeros((B,), jnp.int32)
    t0 = time.perf_counter()
    out = jax.block_until_ready(tide_attention(
        q, ak, av, table, lens, live, interpret=True))
    dt = time.perf_counter() - t0
    ref = tide_attention_ref(q, ak, av, table, lens, live)
    err = float(jnp.max(jnp.abs(out - ref)))
    # HBM bytes: kernel streams each K/V block exactly once per kv-head;
    # reference path materializes a full gathered copy first (2× traffic).
    kernel_bytes = 2 * B * NB * blk * KH * dk * 4
    ref_bytes = 2 * kernel_bytes
    csv(f"kernel.tide_attention.allclose,{err:.2e},"
        f"max|err| vs oracle (interp {dt*1e3:.0f}ms)")
    csv(f"kernel.tide_attention.hbm_bytes,{kernel_bytes},"
        f"vs reference-path {ref_bytes} (gather copy eliminated)")
