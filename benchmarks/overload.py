"""Overload suite: admission control vs the unbounded baseline.

The scenario is sustained overload — an offered load of ``OFFERED_X``
(default 4×) requests per ``step()`` beyond what one step drains
(``max_batch``).  Four cases on the same pre-filled store:

- ``saturation``: queue always exactly one batch deep — the server's
  ceiling throughput, the denominator for the goodput gate.
- ``baseline``: no admission.  The queue grows without bound round over
  round and per-request sojourn time (p99) grows with it — the failure
  mode the controller exists to delete.
- ``shed``: cost-bounded admission, fail-fast policy.  Queue depth stays
  at/below the high watermark, excess submissions get ``Overloaded``, and
  the requests that ARE admitted retire at full batches — goodput holds
  near saturation while the baseline drowns.
- ``backpressure``: producers park instead of shedding; every submitted
  request is eventually served (zero loss), queue cost never passes the
  watermark.

Emits ``BENCH_overload.json`` (schema ``overload/v1``)::

    {
      "schema": "overload/v1",
      "engine": "tidehunter",
      "offered_x": 4.0, "rounds": 64, "max_batch": 64,
      "high_watermark": 64.0,
      "results": [
        {"case": "saturation", "served": 4096, "ops_per_s": 81000.0,
         "serve_ops_per_s": 93000.0},
        {"case": "baseline", "served": ...,
         "peak_queue_depth": 12288, "final_queue_depth": 12288,
         "p99_sojourn_ms": 930.0, ...},
        {"case": "shed", "served": ...,
         "peak_queue_depth": 64, "peak_queued_cost": 64.0, "shed": ...,
         "p99_sojourn_ms": 2.1, "goodput_vs_saturation": 0.97, ...},
        {"case": "backpressure", "served": ..., "peak_queued_cost": 64.0,
         "lost": 0, "goodput_vs_saturation": 0.95, ...}
      ],
      "acceptance": {"queue_bounded": true, "goodput_ok": true,
                     "zero_loss": true}
    }

``ops_per_s`` is wall clock; ``serve_ops_per_s`` is served ops per second
of time spent inside ``step()``.  Goodput gates on the latter: producer
and server share one core in this bench, so wall clock charges the load
generator's cost (including the exception raised per shed rejection) to
the server, which in a real deployment lands on remote clients.

Acceptance (checked by the full run, recorded in the JSON): admission
holds queue depth ≤ the high watermark while the baseline's final queue
is unbounded (≥ ``OFFERED_X - 1`` batches per round), and shed goodput is
≥ 0.8× saturation.  ``python -m benchmarks.overload --smoke`` runs a tiny
configuration and exits non-zero unless the queue stays bounded and the
store degrades gracefully (served > 0 under overload, baseline queue
visibly unbounded) — correctness shapes, not timing, so it cannot flake
on a loaded runner.
"""
from __future__ import annotations

import json
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.core.tidestore import DbConfig, KeyspaceConfig, TideDB
from repro.core.tidestore.wal import WalConfig
from repro.serving.admission import AdmissionConfig, Overloaded
from repro.serving.engine import KvBatchServer

from .engines import gen_keys

OFFERED_X = 4              # offered load, in multiples of one step's drain


def _cfg():
    return DbConfig(
        keyspaces=[KeyspaceConfig("default", n_cells=64,
                                  dirty_flush_threshold=100000)],
        wal=WalConfig(segment_size=8 * 1024 * 1024, background=False),
        index_wal=WalConfig(segment_size=32 * 1024 * 1024, background=False),
        background_snapshots=False,
    )


def _p99_ms(reqs) -> float:
    waits = [(r.t_done - r.t_submit) * 1e3 for r in reqs
             if r.done and r.t_done is not None]
    return float(np.percentile(waits, 99)) if waits else 0.0


def _mixed_submit(srv, keys, i):
    """9:1 read/write mix, the serving loop's bread and butter."""
    k = keys[i % len(keys)]
    if i % 10 == 9:
        return srv.submit_put(k, b"v" * 64)
    return srv.submit_get(k)


def _timed_step(srv, acc):
    """One ``step()``, its duration accumulated into ``acc[0]``.

    Wall clock lumps the load generator's cost (including the exception
    per shed rejection) into the server's throughput — an artifact of
    producer and server sharing one core in this bench.  Goodput is
    therefore served ops per second of *server* time, uniformly for every
    case; wall-clock ops/s is recorded alongside."""
    t0 = time.perf_counter()
    n = srv.step()
    acc[0] += time.perf_counter() - t0
    return n


def _rates(served, step_s, wall_s):
    return {"served": served,
            "ops_per_s": served / wall_s if wall_s > 0 else 0.0,
            "serve_ops_per_s": served / step_s if step_s > 0 else 0.0}


def _case_saturation(db, keys, rounds, max_batch):
    srv = KvBatchServer(db, max_batch=max_batch)
    served, step_s = 0, [0.0]
    t0 = time.perf_counter()
    for r in range(rounds):
        for i in range(max_batch):
            _mixed_submit(srv, keys, r * max_batch + i)
        served += _timed_step(srv, step_s)
    wall = time.perf_counter() - t0
    return {"case": "saturation", **_rates(served, step_s[0], wall)}


def _case_baseline(db, keys, rounds, max_batch):
    srv = KvBatchServer(db, max_batch=max_batch)
    reqs, served, peak, step_s = [], 0, 0, [0.0]
    t0 = time.perf_counter()
    for r in range(rounds):
        for i in range(OFFERED_X * max_batch):
            reqs.append(_mixed_submit(srv, keys, r * max_batch + i))
        peak = max(peak, len(srv.queue))
        served += _timed_step(srv, step_s)
    wall = time.perf_counter() - t0
    return {"case": "baseline", **_rates(served, step_s[0], wall),
            "peak_queue_depth": peak,
            "final_queue_depth": len(srv.queue),
            "p99_sojourn_ms": _p99_ms(reqs)}


def _case_shed(db, keys, rounds, max_batch, high):
    srv = KvBatchServer(db, max_batch=max_batch,
                        admission=AdmissionConfig(high_watermark=high,
                                                  policy="shed"))
    reqs, served, shed, peak, step_s = [], 0, 0, 0, [0.0]
    t0 = time.perf_counter()
    for r in range(rounds):
        for i in range(OFFERED_X * max_batch):
            try:
                reqs.append(_mixed_submit(srv, keys, r * max_batch + i))
            except Overloaded:
                shed += 1
        peak = max(peak, len(srv.queue))
        served += _timed_step(srv, step_s)
    wall = time.perf_counter() - t0
    s = srv.admission.stats()
    return {"case": "shed", **_rates(served, step_s[0], wall),
            "peak_queue_depth": peak,
            "peak_queued_cost": s["admission_peak_cost"],
            "shed": shed, "p99_sojourn_ms": _p99_ms(reqs)}


def _case_backpressure(db, keys, rounds, max_batch, high):
    srv = KvBatchServer(db, max_batch=max_batch,
                        admission=AdmissionConfig(high_watermark=high))
    total = rounds * max_batch
    reqs, lock = [], threading.Lock()

    def producer(base):
        for i in range(total // 2):
            r = _mixed_submit(srv, keys, base + i)
            with lock:
                reqs.append(r)

    threads = [threading.Thread(target=producer, args=(j * total,),
                                daemon=True) for j in range(2)]
    served, step_s = 0, [0.0]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    while served < total:
        n = _timed_step(srv, step_s)
        if n == 0:          # producers mid-submit: yield instead of spinning
            time.sleep(0.0005)
        served += n
    wall = time.perf_counter() - t0
    for t in threads:
        t.join(10.0)
    s = srv.admission.stats()
    lost = sum(1 for r in reqs if not r.done)
    return {"case": "backpressure", **_rates(served, step_s[0], wall),
            "peak_queued_cost": s["admission_peak_cost"],
            "waits": s["admission_waits"], "lost": lost}


def run(rounds: int = 64, max_batch: int = 64, n_keys: int = 4096,
        best_of: int = 3, csv=print,
        json_path: str | None = "BENCH_overload.json") -> dict:
    keys = gen_keys(n_keys, seed=23)
    high = float(max_batch)       # watermark = one full batch of unit reads
    d = tempfile.mkdtemp(prefix="bench-overload-")

    def best(case_fn, *a):        # best-of-N serve rate, 1-core noise guard
        return max((case_fn(db, keys, *a) for _ in range(best_of)),
                   key=lambda r: r["serve_ops_per_s"])

    try:
        db = TideDB(d, _cfg())
        db.put_many([(k, b"v" * 64) for k in keys])
        db.multi_get(keys)        # warm the read path before timing
        _case_saturation(db, keys, max(1, rounds // 8), max_batch)
        sat = best(_case_saturation, rounds, max_batch)
        base = best(_case_baseline, rounds, max_batch)
        shed = best(_case_shed, rounds, max_batch, high)
        bp = best(_case_backpressure, rounds, max_batch, high)
        db.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)

    for row in (shed, bp):
        row["goodput_vs_saturation"] = (
            row["serve_ops_per_s"] / sat["serve_ops_per_s"]
            if sat["serve_ops_per_s"] else 0.0)
    acceptance = {
        # admission bounds the queue at the watermark; the baseline's
        # final queue is the un-drained excess (OFFERED_X-1 batches/round)
        "queue_bounded": (shed["peak_queue_depth"] <= high
                          and shed["peak_queued_cost"] <= high
                          and bp["peak_queued_cost"] <= high),
        "baseline_unbounded": (base["final_queue_depth"]
                               >= (OFFERED_X - 1) * max_batch * rounds // 2),
        "goodput_ok": shed["goodput_vs_saturation"] >= 0.8,
        "zero_loss": bp["lost"] == 0,
    }

    csv(f"overload.saturation,{1e6/sat['serve_ops_per_s']:.2f},"
        f"{sat['serve_ops_per_s']:.0f} served-ops/s "
        f"(wall {sat['ops_per_s']:.0f})")
    csv(f"overload.baseline,{1e6/base['serve_ops_per_s']:.2f},"
        f"{base['serve_ops_per_s']:.0f} served-ops/s "
        f"queue={base['final_queue_depth']} "
        f"p99={base['p99_sojourn_ms']:.1f}ms")
    csv(f"overload.shed,{1e6/shed['serve_ops_per_s']:.2f},"
        f"{shed['serve_ops_per_s']:.0f} served-ops/s "
        f"({shed['goodput_vs_saturation']:.2f}x sat) "
        f"queue<={shed['peak_queue_depth']} shed={shed['shed']} "
        f"p99={shed['p99_sojourn_ms']:.1f}ms")
    csv(f"overload.backpressure,{1e6/bp['serve_ops_per_s']:.2f},"
        f"{bp['serve_ops_per_s']:.0f} served-ops/s "
        f"({bp['goodput_vs_saturation']:.2f}x sat) lost={bp['lost']}")
    csv(f"overload.acceptance,0,{acceptance}")

    out = {"schema": "overload/v1", "engine": "tidehunter",
           "offered_x": float(OFFERED_X), "rounds": rounds,
           "max_batch": max_batch, "high_watermark": high,
           "results": [sat, base, shed, bp], "acceptance": acceptance}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=1)
        csv(f"overload.json,0,{json_path}")
    return out


def run_smoke(csv=print) -> bool:
    """CI gates — correctness shapes, not timing: (a) bounded queue under
    4× overload (depth and accounted cost never pass the watermark);
    (b) graceful degradation (the admitted stream is still served:
    served > 0 every round, all admitted requests retire); (c) the
    baseline really is unbounded (the scenario isn't vacuous);
    (d) backpressure loses nothing."""
    out = run(rounds=8, max_batch=16, n_keys=512, csv=csv, json_path=None)
    a = out["acceptance"]
    shed = next(r for r in out["results"] if r["case"] == "shed")
    ok = (a["queue_bounded"] and a["baseline_unbounded"] and a["zero_loss"]
          and shed["served"] > 0)
    csv(f"overload.smoke,0,{'ok' if ok else 'FAIL'} "
        f"(bounded={a['queue_bounded']} degraded_gracefully="
        f"{shed['served'] > 0} zero_loss={a['zero_loss']})")
    return ok


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="bounded-queue + graceful-degradation gates under "
                         "4x overload; correctness shapes, not timing")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if run_smoke() else 1)
    run()
