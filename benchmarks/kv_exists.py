"""kvexists suite: the existence path — scalar vs per-cell vs fused probes.

The paper's headline existence-check win (15.6×, §4) rests on resolving
``exists`` entirely from the in-memory filters.  This suite measures the
three generations of that path over a sweep of batch size × touched-cell
count:

- ``scalar``  — one ``might_contain`` per key (hashing inside), the §3.2
  scalar existence gate.
- ``percell`` — the pre-fusion batched pipeline: keys hash once, then one
  ``might_contain_many`` per touched cell, i.e. one ``bloom_check``
  dispatch per cell at ≥64 queries/cell (numpy below).
- ``fused``   — ONE ragged ``probe_cells`` call across every touched cell:
  bitsets packed, per-query cell offsets/moduli, a single kernel dispatch
  (or one vectorized numpy pass below the threshold).

A db-level probe times ``TideDB.multi_exists`` against a scalar ``exists``
loop on flushed (UNLOADED) cells and records the fused-dispatch count for
the batch — which must be exactly 1.

Emits ``BENCH_kvexists.json`` (schema ``kvexists/v1``)::

    {
      "schema": "kvexists/v1",
      "engine": "tidehunter",
      "keys_per_cell": 512,
      "results": [
        {"mode": "scalar|percell|fused", "n_cells": 16, "batch": 256,
         "us_per_op": 1.2, "ops_per_s": 830000.0,
         "speedup_vs_scalar": 9.0,
         "speedup_vs_percell": 3.1},        # fused rows only
        ...
      ],
      "db_probe": {"batch": 1024, "multi_exists_us_per_op": ...,
                   "scalar_exists_us_per_op": ..., "speedup": ...,
                   "fused_dispatches": 1}
    }

Acceptance bar (asserted by the full run's summary line, recorded in the
JSON): fused ≥ 2× the per-cell path at batch ≥ 256 on ≥ 16 cells.
``python -m benchmarks.kv_exists --smoke`` runs one tiny configuration and
exits non-zero unless fused ≥ per-cell throughput — a CI sanity bound far
below the 2× bar so loaded runners can't flake it.
"""
from __future__ import annotations

import json
import time

from .engines import gen_keys

CELL_COUNTS = (4, 16, 64)
BATCH_SIZES = (64, 256, 1024)
KEYS_PER_CELL = 512


def _build_cells(n_cells: int, keys_per_cell: int):
    from repro.core.tidestore.bloom import BloomFilter
    cells, added = [], []
    for ci in range(n_cells):
        bf = BloomFilter(keys_per_cell, bits_per_key=10)
        ks = gen_keys(keys_per_cell, seed=10_000 + ci)
        bf.add_many(ks)
        cells.append(bf)
        added.append(ks)
    return cells, added


def _mk_queries(added, batch: int):
    """Round-robin queries over the cells, half present / half absent;
    returns (queries, groups) with groups[i] = query indices probing
    cell i (ragged when batch % n_cells != 0)."""
    import numpy as np
    n_cells = len(added)
    absent = gen_keys(batch, seed=77)
    queries, groups = [], [[] for _ in range(n_cells)]
    for i in range(batch):
        ci = i % n_cells
        key = added[ci][i % len(added[ci])] if i % 2 == 0 else absent[i]
        groups[ci].append(len(queries))
        queries.append(key)
    return queries, [np.asarray(g, dtype=np.int64) for g in groups]


def _best(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(cell_counts=CELL_COUNTS, batch_sizes=BATCH_SIZES,
        keys_per_cell: int = KEYS_PER_CELL, reps: int = 5, csv=print,
        json_path: str | None = "BENCH_kvexists.json",
        db_probe: bool = True) -> dict:
    """Returns ``{(n_cells, batch): {mode: ops_per_s}}`` and (optionally)
    writes the ``kvexists/v1`` JSON trajectory."""
    from repro.core.tidestore.bloom import key_hashes_many, probe_cells

    results: list[dict] = []
    rates: dict = {}

    def record(mode, nc, bs, dt, extra=None):
        row = {"mode": mode, "n_cells": nc, "batch": bs,
               "us_per_op": dt / bs * 1e6, "ops_per_s": bs / dt}
        row.update(extra or {})
        results.append(row)
        tail = "".join(f" ({v:.1f}x {k[11:]})" for k, v in (extra or {}).items())
        csv(f"kvexists.c{nc}.b{bs}.{mode},{dt/bs*1e6:.2f},"
            f"{bs/dt:.0f} ops/s{tail}")
        return bs / dt

    for nc in cell_counts:
        cells, added = _build_cells(nc, keys_per_cell)
        for bs in batch_sizes:
            queries, groups = _mk_queries(added, bs)
            # Both batched pipelines hash once per batch (pre- and
            # post-fusion alike), so the hashes are precomputed and the
            # timed region isolates the probe paths; the scalar mode hashes
            # per key inside the loop — that IS the scalar op.
            h1, h2 = key_hashes_many(queries)

            def scalar():
                for g, bf in zip(groups, cells):
                    for qi in g:
                        bf.might_contain(queries[qi])

            def percell():
                # Pre-fusion pipeline: one dispatch per touched cell.
                for g, bf in zip(groups, cells):
                    if g.size:
                        bf.might_contain_many((), h1=h1[g], h2=h2[g],
                                              use_kernel=True)

            def fused():
                probe_cells(cells, h1, h2, groups, use_kernel=True)

            percell()          # warm the jit caches for both shapes
            fused()
            dt_s = _best(scalar, reps)
            dt_p = _best(percell, reps)
            dt_f = _best(fused, reps)
            r_s = record("scalar", nc, bs, dt_s)
            r_p = record("percell", nc, bs, dt_p,
                         {"speedup_vs_scalar": dt_s / dt_p})
            r_f = record("fused", nc, bs, dt_f,
                         {"speedup_vs_scalar": dt_s / dt_f,
                          "speedup_vs_percell": dt_p / dt_f})
            rates[(nc, bs)] = {"scalar": r_s, "percell": r_p, "fused": r_f}

    bar = [dt_pc / dt_fu for (nc, bs), m in rates.items()
           if nc >= 16 and bs >= 256
           for dt_pc, dt_fu in [(1 / m["percell"], 1 / m["fused"])]]
    bar_ok = bool(bar) and min(bar) >= 2.0
    if bar and json_path:
        # The 2x bar belongs to the full recorded run only; a smoke run
        # (json_path=None) enforces its own >=1x bound and must not print
        # a MISSED line for a bound it deliberately doesn't gate on.
        csv(f"kvexists.bar,0,fused>=2x percell at b>=256/c>=16: "
            f"min {min(bar):.1f}x {'ok' if bar_ok else 'MISSED'}")

    probe_row = None
    if db_probe:
        probe_row = _db_probe(csv)

    if json_path:
        doc = {"schema": "kvexists/v1", "engine": "tidehunter",
               "keys_per_cell": keys_per_cell, "results": results,
               "fused_ge_2x_percell_at_b256_c16": bar_ok}
        if probe_row:
            doc["db_probe"] = probe_row
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=1)
        csv(f"kvexists.json,0,{json_path}")
    return rates


def _db_probe(csv) -> dict:
    """End-to-end probe: ``multi_exists`` vs a scalar ``exists`` loop on a
    store whose cells are flushed (UNLOADED, Bloom-gated), plus the fused
    dispatch count for one batch — the one-dispatch-per-store invariant."""
    import shutil
    import tempfile

    from repro.core.tidestore import DbConfig, KeyspaceConfig, TideDB
    from repro.core.tidestore.wal import WalConfig
    from repro.kernels.bloom_check import ops as bloom_ops

    d = tempfile.mkdtemp(prefix="bench-kvexists-")
    # blob_cache_bytes=0 keeps the Bloom gate live on every call (a
    # memoized blob legitimately skips it); 8 cells × a 1024-key batch
    # crosses the fused kernel threshold, so the dispatch count is the
    # kernel-path invariant, not the numpy fallback.
    cfg = DbConfig(keyspaces=[KeyspaceConfig("default", n_cells=8,
                                             dirty_flush_threshold=100_000)],
                   wal=WalConfig(segment_size=4 * 1024 * 1024,
                                 background=False),
                   index_wal=WalConfig(segment_size=16 * 1024 * 1024,
                                       background=False),
                   background_snapshots=False, cache_bytes=0,
                   blob_cache_bytes=0)
    try:
        with TideDB(d, cfg) as db:
            present = gen_keys(2048, seed=1)
            absent = gen_keys(1024, seed=2)
            db.put_many([(k, b"v" * 64) for k in present])
            db.snapshot_now(flush_threshold=1)
            batch = present[:512] + absent[:512]
            db.multi_exists(batch)            # warm jit shapes + blob memo
            before = bloom_ops.ragged_dispatch_count
            db.multi_exists(batch)
            dispatches = bloom_ops.ragged_dispatch_count - before
            dt_b = _best(lambda: db.multi_exists(batch), 3)
            dt_s = _best(lambda: [db.exists(k) for k in batch], 3)
            row = {"batch": len(batch),
                   "multi_exists_us_per_op": dt_b / len(batch) * 1e6,
                   "scalar_exists_us_per_op": dt_s / len(batch) * 1e6,
                   "speedup": dt_s / dt_b,
                   "fused_dispatches": dispatches}
            csv(f"kvexists.db.b{len(batch)},{dt_b/len(batch)*1e6:.2f},"
                f"{row['speedup']:.1f}x scalar exists, "
                f"{dispatches} fused dispatch(es)/batch")
            return row
    finally:
        shutil.rmtree(d, ignore_errors=True)


def run_smoke(csv=print) -> bool:
    """CI sanity bound: the fused probe must not lose to the per-cell path.

    One tiny configuration, no JSON — asserts fused ≥ 1.0× per-cell (the
    real acceptance bar is ≥ 2×; this bound exists to catch routing
    regressions without becoming a flaky timing gate)."""
    rates = run(cell_counts=(16,), batch_sizes=(256,), reps=3, csv=csv,
                json_path=None, db_probe=False)
    m = rates[(16, 256)]
    ok = m["fused"] >= m["percell"]
    csv(f"kvexists.smoke,0,{'ok' if ok else 'FAIL: fused < percell'}")
    return ok


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run; exit 1 unless fused >= percell")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if run_smoke() else 1)
    run()
