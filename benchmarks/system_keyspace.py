"""System-keyspace suite: what does self-observation cost, and is it right?

The ``__system`` keyspace (top-N large values, hot cells, per-keyspace
rollups) is populated from the hot put/read paths through sampled,
lock-free counters — the design bet is that observation is nearly free.
This suite prices that bet: put and multi_get throughput with
``system_stats`` on (default sampling), on with ``sample=1`` (every key
attributed — the worst case), and off, plus the cost of one ``fold()``
per snapshot.

``--smoke`` is the CI gate and checks correctness, not timing: the
``large_values`` table must match an independently computed top-N oracle
exactly, survive a crash-reopen, and the observation overhead path must
not disturb user reads.
"""
from __future__ import annotations

import shutil
import tempfile
import time

from repro.core.tidestore import DbConfig, KeyspaceConfig, TideDB
from repro.core.tidestore.wal import WalConfig

from .engines import gen_keys


def _cfg(**kw):
    defaults = dict(
        keyspaces=[KeyspaceConfig("default", n_cells=64,
                                  dirty_flush_threshold=100000)],
        wal=WalConfig(segment_size=8 * 1024 * 1024, background=False),
        index_wal=WalConfig(segment_size=32 * 1024 * 1024, background=False),
        background_snapshots=False,
    )
    defaults.update(kw)
    return DbConfig(**defaults)


def _time_workload(cfg, keys, value, batch=256):
    d = tempfile.mkdtemp(prefix="bench-system-")
    try:
        db = TideDB(d, cfg)
        t0 = time.perf_counter()
        for off in range(0, len(keys), batch):
            db.put_many([(k, value) for k in keys[off:off + batch]])
        put_dt = time.perf_counter() - t0
        t0 = time.perf_counter()
        for off in range(0, len(keys), batch):
            db.multi_get(keys[off:off + batch])
        get_dt = time.perf_counter() - t0
        fold_dt = 0.0
        if db.system is not None:
            t0 = time.perf_counter()
            db.system.fold()
            fold_dt = time.perf_counter() - t0
        db.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)
    return put_dt, get_dt, fold_dt


def run(n_keys: int = 16384, value_size: int = 256, csv=print) -> dict:
    keys = gen_keys(n_keys, seed=29)
    value = bytes(value_size)
    cases = [("off", _cfg(system_stats=False)),
             ("sampled", _cfg()),
             ("sample1", _cfg(system_sample=1))]
    out: dict = {}
    base_put = base_get = None
    for name, cfg in cases:
        put_dt, get_dt, fold_dt = _time_workload(cfg, keys, value)
        out[name] = (put_dt, get_dt, fold_dt)
        if name == "off":
            base_put, base_get = put_dt, get_dt
        put_oh = (put_dt / base_put - 1) * 100 if base_put else 0.0
        get_oh = (get_dt / base_get - 1) * 100 if base_get else 0.0
        csv(f"system.put.{name},{put_dt/n_keys*1e6:.2f},"
            f"{n_keys/put_dt:.0f} ops/s ({put_oh:+.1f}% vs off)")
        csv(f"system.get.{name},{get_dt/n_keys*1e6:.2f},"
            f"{n_keys/get_dt:.0f} ops/s ({get_oh:+.1f}% vs off)")
        if name != "off":
            csv(f"system.fold.{name},{fold_dt*1e6:.0f},"
                f"{fold_dt*1e3:.2f} ms per fold")
    return out


def run_smoke(csv=print) -> bool:
    """CI gates: (a) ``large_values`` matches an independent top-N oracle;
    (b) the tables survive a crash-reopen (fold + snapshot, close without
    flush); (c) user reads are undisturbed by observation."""
    keys = gen_keys(600, seed=31)
    sizes = [64 + ((i * 7919) % 4096) for i in range(len(keys))]
    d = tempfile.mkdtemp(prefix="bench-system-smoke-")
    ok = True
    try:
        cfg = _cfg(system_top_n=8)
        db = TideDB(d, cfg)
        db.put_many([(k, b"x" * s) for k, s in zip(keys, sizes)])
        want = sorted(zip(keys, sizes), key=lambda kv: (-kv[1], kv[0]))[:8]
        got = [(r["key"], r["size"])
               for r in db.system_tables()["large_values"]["default"]]
        oracle_ok = got == want
        ok &= oracle_ok
        db.snapshot_now()
        db.close(flush=False)                  # crash
        db2 = TideDB(d, cfg)
        t = db2.system_tables()
        reopen_ok = (t["keyspace_stats"]["default"]["puts"] == len(keys)
                     and [(r["key"], r["size"])
                          for r in t["large_values"]["default"]] == want)
        ok &= reopen_ok
        reads_ok = all(db2.get(k) == b"x" * s
                       for k, s in zip(keys[:50], sizes[:50]))
        ok &= reads_ok
        db2.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)
    csv(f"system.smoke,0,{'ok' if ok else 'FAIL'} "
        f"(oracle={oracle_ok} reopen={reopen_ok} reads={reads_ok})")
    return bool(ok)


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="top-N oracle parity + crash-reopen survival gates")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if run_smoke() else 1)
    run()
