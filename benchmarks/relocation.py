"""Space-amplification trajectory under churn (paper Figure 9 / §4.4).

Pre-fill, then run churn rounds — overwrite-heavy + delete-heavy foreground
``put_many``/``delete_many`` traffic, each round tagged with its own epoch —
with reclamation OFF (the log only grows) and ON (the ``PruneController``
interleaves bounded relocation slices between foreground batches, exactly
the way ``KvBatchServer`` schedules them, and epoch expiry drops whole
retired segments for free).  Per round we sample the physical WAL span, the
on-disk bytes, the controller's space-amp estimate, and foreground write
throughput.

The reproduction targets:

- with reclamation ON, space amplification stays bounded under churn while
  it grows without bound OFF (segments are reclaimed *under live traffic*);
- foreground ``put_many`` throughput with reclamation ON stays ≥ 0.8× the
  no-reclamation baseline — relocation rides the same reserve→copy→commit
  batched write protocol as the foreground, so its interference is one
  allocation-lock acquisition + one CopyPool fan-out per harvest batch.

Emits ``BENCH_relocation.json`` (schema ``relocation/v1``)::

    {
      "schema": "relocation/v1",
      "engine": "tidehunter",
      "n_keys": 4000, "value_size": 512, "rounds": 6,
      "prune": {"space_amp_trigger": 1.5, "retain_epochs": 3, ...},
      "modes": {
        "off": {"puts_per_s": ..., "final_span_bytes": ...,
                "final_disk_bytes": ...,
                "trajectory": [{"round": 1, "span_bytes": ...,
                                "disk_bytes": ..., "space_amp": ...,
                                "segments_dropped": ...,
                                "relocated_entries": ...,
                                "puts_per_s": ...}, ...]},
        "on": {... same shape ...}
      },
      "foreground_ratio": 0.93,          # on/off puts_per_s
      "span_ratio": 0.31,                # on/off final span
      "reclaimed_segments": 14
    }

``python -m benchmarks.relocation --smoke`` runs a tiny configuration
(best-of-2 per mode) and exits non-zero unless segments were reclaimed
under live traffic, the final span shrank vs the OFF baseline, and the
foreground throughput ratio held ≥ 0.8.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.tidestore import (DbConfig, KeyspaceConfig, PruneOptions,
                                  TideDB)
from repro.core.tidestore.wal import WalConfig

from .engines import Bench, gen_keys


def _disk_bytes(path: str) -> int:
    total = 0
    for fn in os.listdir(path):
        if fn.endswith(".seg"):
            st = os.stat(os.path.join(path, fn))
            total += st.st_blocks * 512       # sparse-aware
    return total


def _prune_opts() -> PruneOptions:
    # Epoch expiry does the heavy lifting (whole segments drop for free);
    # relocation only mops up the residual overwrite churn, so the trigger
    # is lazy — every relocated byte is foreground CPU on a 1-core runner.
    return PruneOptions(space_amp_trigger=2.5, reclaim_fraction=0.35,
                        min_reclaim_bytes=512 * 1024, retain_epochs=3,
                        batch_records=256)


def _churn_tide(path, prune_on: bool):
    # Segments small enough that epoch expiry retires whole files within
    # the scaled run; reclamation policy rides DbConfig.prune.
    return TideDB(path, DbConfig(
        keyspaces=[KeyspaceConfig("default", n_cells=64,
                                  dirty_flush_threshold=2048)],
        wal=WalConfig(segment_size=256 * 1024),
        index_wal=WalConfig(segment_size=16 * 1024 * 1024),
        cache_bytes=4 * 1024 * 1024,
        prune=_prune_opts() if prune_on else None,
    ))


def _run_mode(prune_on: bool, n_keys: int, value_size: int, rounds: int,
              batch: int, seed: int = 7) -> dict:
    """One churn run; returns the mode's summary + per-round trajectory.

    Foreground traffic is batched writes; with reclamation ON, one bounded
    ``prune_step`` (at most one harvest batch re-appended through ONE
    ``append_many``) runs after every foreground batch — the serving
    loop's scheduling, so reclamation progress is paid for in-line and the
    measured throughput honestly includes it."""
    b = Bench("tidehunter", lambda p: _churn_tide(p, prune_on))
    db = b.db
    keys = gen_keys(n_keys, seed=seed)
    rng = np.random.default_rng(seed)
    value = bytes(value_size)

    db.put_many([(k, value) for k in keys], epoch=1)
    live = set(range(n_keys))
    last_epoch = {i: 1 for i in range(n_keys)}   # latest write round per key
    trajectory = []
    total_puts = total_s = 0.0
    for r in range(2, rounds + 2):
        # overwrite-heavy + delete-heavy churn: half the keyspace
        # rewritten into this round's epoch, a quarter deleted, deleted
        # keys from earlier rounds resurrected
        over = rng.choice(n_keys, n_keys // 2, replace=False)
        dead = set(int(i) for i in over[:n_keys // 4])
        puts = [int(i) for i in over[n_keys // 4:]] + \
               [i for i in range(n_keys) if i not in live][:n_keys // 8]
        t0 = time.perf_counter()
        for off in range(0, len(puts), batch):
            db.put_many([(keys[i], value) for i in puts[off:off + batch]],
                        epoch=r)
            if prune_on:
                db.prune_step()
        dels = sorted(dead)
        for off in range(0, len(dels), batch):
            db.delete_many([keys[i] for i in dels[off:off + batch]],
                           epochs=[r] * len(dels[off:off + batch]))
            if prune_on:
                db.prune_step()
        dt = time.perf_counter() - t0
        live |= set(puts)
        live -= dead
        for i in puts:
            last_epoch[i] = r
        total_puts += len(puts) + len(dels)
        total_s += dt
        st = db.stats()
        trajectory.append({
            "round": r - 1,
            "span_bytes": st["wal_live_bytes"],
            "disk_bytes": _disk_bytes(b.dir),
            "space_amp": (db.prune_controller.space_amp()
                          if prune_on else None),
            "segments_dropped": st.get("segments_deleted", 0)
                                + st.get("segments_pruned", 0),
            "relocated_entries": st.get("relocated_entries", 0),
            "cas_fail": st.get("relocation_cas_fail", 0),
            "puts_per_s": (len(puts) + len(dels)) / dt,
        })
    # drain: with reclamation ON, finish any in-flight pass so the final
    # span reflects steady state (a server would keep stepping while idle)
    if prune_on:
        for _ in range(10_000):
            if db.prune_step() == 0 and not db.relocator.scanning:
                break
    db.snapshot_now()
    db.value_wal._mapper_once()
    st = db.stats()
    out = {
        "puts_per_s": total_puts / total_s,
        "final_span_bytes": db.value_wal.tail - db.value_wal.first_live_pos,
        "final_disk_bytes": _disk_bytes(b.dir),
        "segments_dropped": st.get("segments_deleted", 0)
                            + st.get("segments_pruned", 0),
        "relocated_entries": st.get("relocated_entries", 0),
        "relocation_batches": st.get("relocation_batches", 0),
        "cas_fail": st.get("relocation_cas_fail", 0),
        "trajectory": trajectory,
    }
    # correctness spot-check: churn + relocation must not lose live keys.
    # Epoch expiry is *semantic retirement* (paper §4.4): keys whose last
    # write aged past retain_epochs may legitimately be dropped wholesale,
    # so only keys inside the retained epoch window are asserted readable.
    retain = _prune_opts().retain_epochs or 0
    floor = (rounds + 1) - retain + 1 if prune_on and retain else 0
    warm = sorted(i for i in live if last_epoch[i] >= floor)
    probe = rng.choice(warm, min(64, len(warm)), replace=False)
    for i in probe:
        assert db.get(keys[int(i)]) == value, "live key lost under churn"
    b.close()
    return out


def run(n_keys: int = 4000, value_size: int = 512, rounds: int = 6,
        batch: int = 256, best_of: int = 1, csv=print,
        json_path: str | None = "BENCH_relocation.json") -> dict:
    modes = {}
    for name, on in (("off", False), ("on", True)):
        runs = [_run_mode(on, n_keys, value_size, rounds, batch)
                for _ in range(best_of)]
        modes[name] = max(runs, key=lambda m: m["puts_per_s"])
        # Per-run throughputs ride along so gates can measure the runner's
        # OWN noise floor (spread across identical runs) instead of
        # hard-coding a margin that flakes on loaded machines.
        modes[name]["runs_puts_per_s"] = [r["puts_per_s"] for r in runs]
        m = modes[name]
        csv(f"reloc.{name}.puts_per_s,{1e6/m['puts_per_s']:.2f},"
            f"{m['puts_per_s']:.0f} ops/s")
        csv(f"reloc.{name}.final_span,{m['final_span_bytes']},"
            f"disk={m['final_disk_bytes']}B "
            f"segments_dropped={m['segments_dropped']}")
    ratio = modes["on"]["puts_per_s"] / max(modes["off"]["puts_per_s"], 1e-9)
    span_ratio = (modes["on"]["final_span_bytes"]
                  / max(modes["off"]["final_span_bytes"], 1))
    csv(f"reloc.foreground_ratio,{ratio*100:.1f},"
        f"{ratio:.2f}x of no-reclamation baseline")
    csv(f"reloc.span_ratio,{span_ratio*100:.1f},"
        f"final span {span_ratio:.2f}x of baseline "
        f"(relocated={modes['on']['relocated_entries']} "
        f"batches={modes['on']['relocation_batches']} "
        f"cas_fail={modes['on']['cas_fail']})")
    report = {
        "schema": "relocation/v1", "engine": "tidehunter",
        "n_keys": n_keys, "value_size": value_size, "rounds": rounds,
        "batch": batch,
        "prune": {k: getattr(_prune_opts(), k)
                  for k in ("strategy", "reclaim_fraction",
                            "space_amp_trigger", "min_reclaim_bytes",
                            "retain_epochs", "batch_records")},
        "modes": modes,
        "foreground_ratio": ratio,
        "span_ratio": span_ratio,
        "reclaimed_segments": modes["on"]["segments_dropped"],
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
        csv(f"reloc.json,0,{json_path}")
    return report


def run_smoke(csv=print) -> bool:
    """CI bound: under churn with live foreground traffic, reclamation must
    (a) actually drop segments, (b) shrink the final physical span vs the
    no-reclamation baseline, and (c) keep foreground batched-write
    throughput ≥ 0.8× that baseline *after discounting the runner's own
    noise*: the OFF mode runs twice on identical work, so the spread
    between its runs (min/max) measures how noisy this machine is right
    now, and the gate scales by it — a loaded CI runner that can't repeat
    its own baseline within 20% can't flake the reclamation verdict."""
    report = run(n_keys=1500, value_size=256, rounds=4, batch=128,
                 best_of=2, csv=csv, json_path=None)
    reclaimed = report["reclaimed_segments"] > 0
    shrunk = report["span_ratio"] < 0.9
    off_runs = report["modes"]["off"]["runs_puts_per_s"]
    noise = min(off_runs) / max(max(off_runs), 1e-9)
    fast = report["foreground_ratio"] >= 0.8 * noise
    ok = reclaimed and shrunk and fast
    csv(f"reloc.smoke,0,{'ok' if ok else 'FAIL'} "
        f"(reclaimed_segments={report['reclaimed_segments']} "
        f"span_ratio={report['span_ratio']:.2f} "
        f"foreground_ratio={report['foreground_ratio']:.2f} "
        f"noise_floor={noise:.2f} gate={0.8 * noise:.2f})")
    return ok


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny churn run; exit 1 unless segments were "
                         "reclaimed under live traffic, the span shrank, "
                         "and foreground throughput held >= 0.8x baseline")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if run_smoke() else 1)
    run()
