"""Paper Figure 9: relocation's effect on storage and throughput.

Pre-fill, run a delete-heavy phase under uniform (θ=0) and skewed (θ=2)
patterns with relocation on/off; report live storage and throughput delta.
"""
from __future__ import annotations

import os
import time

from .engines import Bench, gen_keys, make_tide, zipf_indices


def _disk_bytes(path: str) -> int:
    total = 0
    for fn in os.listdir(path):
        if fn.endswith(".seg"):
            st = os.stat(os.path.join(path, fn))
            total += st.st_blocks * 512       # sparse-aware
    return total


def run(n_keys: int = 8000, value_size: int = 1024, csv=print) -> None:
    for theta in (0.0, 2.0):
        results = {}
        for reloc in (False, True):
            b = Bench("tidehunter", lambda p: make_tide(p, relocation=False))
            keys = gen_keys(n_keys, seed=3)
            b.fill(keys, value_size)
            idx = zipf_indices(n_keys, n_keys, theta, seed=9)
            t0 = time.perf_counter()
            for i in idx:
                b.db.delete(keys[i])
            del_s = time.perf_counter() - t0
            if reloc:
                b.db.relocator.relocate_wal_based()
                b.db.value_wal._mapper_once()
            b.db.snapshot_now()
            live = b.db.stats()["wal_live_bytes"]
            disk = _disk_bytes(b.dir)
            results[reloc] = (live, disk, del_s)
            b.close()
        off, on = results[False], results[True]
        saved = 1 - on[0] / max(off[0], 1)
        csv(f"reloc.t{int(theta)}.live_bytes_off,{off[0]},"
            f"disk={off[1]}")
        csv(f"reloc.t{int(theta)}.live_bytes_on,{on[0]},disk={on[1]}")
        csv(f"reloc.t{int(theta)}.space_saved,{saved*100:.1f},%")
        csv(f"reloc.t{int(theta)}.throughput_delta,"
            f"{(on[2]/off[2]-1)*100:+.1f},% delete-phase time")
