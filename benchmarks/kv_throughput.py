"""Paper Figures 1/6/7/8: throughput vs value size × workload × skew.

Engines: tidehunter, rocksdb(sim), blobdb(sim).  Value sizes 64/128/1024 B;
workloads: 100% write, 50/50 mixed, 100% read (get + exists); skew θ∈{0,2}.
Reports ops/s and the engine write-amplification counters.

``run_batched`` measures the batched read pipeline (§3.2 batched:
``multi_get``/``multi_exists`` through the Bloom + optimistic-lookup Pallas
kernels with coalesced WAL preads) against the equivalent scalar-get loop,
reporting batch-size-vs-throughput and the speedup ratio.
"""
from __future__ import annotations

import time

from .engines import (ENGINES, Bench, gen_keys, make_tide, make_tide_sharded,
                      multi_exists, multi_get, zipf_indices)


def run(n_keys: int = 6000, n_ops: int = 4000, csv=print) -> None:
    for value_size in (64, 128, 1024):
        keys = gen_keys(n_keys, seed=value_size)
        for theta in (0.0, 2.0):
            idx = zipf_indices(n_keys, n_ops, theta, seed=7)
            for name, factory in ENGINES.items():
                b = Bench(name, factory)
                fill_s = b.fill(keys, value_size)
                v = bytes(value_size)

                t0 = time.perf_counter()
                for j, i in enumerate(idx):
                    b.db.put(keys[i], v)
                w_s = time.perf_counter() - t0

                t0 = time.perf_counter()
                for j, i in enumerate(idx):
                    if j % 2 == 0:
                        b.db.get(keys[i])
                    else:
                        b.db.put(keys[i], v)
                m_s = time.perf_counter() - t0

                t0 = time.perf_counter()
                for i in idx:
                    b.db.get(keys[i])
                g_s = time.perf_counter() - t0

                t0 = time.perf_counter()
                for i in idx:
                    b.db.exists(keys[i])
                e_s = time.perf_counter() - t0

                stats = b.db.stats() if hasattr(b.db, "stats") else {}
                wa = 0.0
                if stats.get("bytes_written_app"):
                    wa = stats["bytes_written_disk"] / stats["bytes_written_app"]
                tag = f"kv.v{value_size}.t{int(theta)}.{name}"
                csv(f"{tag}.write,{w_s/n_ops*1e6:.2f},"
                    f"{n_ops/w_s:.0f} ops/s")
                csv(f"{tag}.mixed,{m_s/n_ops*1e6:.2f},{n_ops/m_s:.0f} ops/s")
                csv(f"{tag}.get,{g_s/n_ops*1e6:.2f},{n_ops/g_s:.0f} ops/s")
                csv(f"{tag}.exists,{e_s/n_ops*1e6:.2f},{n_ops/e_s:.0f} ops/s")
                csv(f"{tag}.write_amp,{wa:.2f},fill={fill_s:.1f}s")
                b.close()


def _clear_cache(db) -> None:
    if hasattr(db, "clear_caches"):          # sharded engine
        db.clear_caches()
        return
    cache = getattr(db, "cache", None)
    if cache is not None and hasattr(cache, "clear"):
        cache.clear()


def run_batched(n_keys: int = 6000, n_ops: int = 2048, value_size: int = 128,
                theta: float = 0.0, csv=print,
                batch_sizes=(16, 64, 256, 1024)) -> dict:
    """Batch-size-vs-throughput for the batched read pipeline.

    For each engine and batch size B: time ``n_ops`` point reads issued as
    N scalar ``get`` calls, then the same reads as ``multi_get`` calls of B
    keys, and report both plus the speedup.  Likewise for existence checks
    (half present keys, half misses — the Bloom short-circuit path).
    Returns ``{engine: {batch: speedup}}`` so tests can assert the ≥2×
    acceptance bar without re-parsing CSV.
    """
    speedups: dict = {}
    for name, factory in ENGINES.items():
        b = Bench(name, factory)
        keys = gen_keys(n_keys, seed=13)
        b.fill(keys, value_size)
        idx = zipf_indices(n_keys, n_ops, theta, seed=11)
        miss = gen_keys(n_ops // 2, seed=99)       # never inserted
        exists_probe = [keys[i] for i in idx[:n_ops // 2]] + miss
        tag = f"kvbatch.v{value_size}.t{int(theta)}.{name}"
        speedups[name] = {}

        # Warm the jit caches at every batch size so one-off compile time is
        # not in the timed region (deployments warm once, serve forever).
        for bs in batch_sizes:
            multi_get(b.db, [keys[i] for i in idx[:min(bs, n_ops)]])
            multi_exists(b.db, exists_probe[:bs])

        _clear_cache(b.db)
        t0 = time.perf_counter()
        for i in idx:
            b.db.get(keys[i])
        scalar_get_s = time.perf_counter() - t0

        _clear_cache(b.db)
        t0 = time.perf_counter()
        for i in idx:
            b.db.exists(keys[i])
        scalar_exists_s = time.perf_counter() - t0

        csv(f"{tag}.scalar_get,{scalar_get_s/n_ops*1e6:.2f},"
            f"{n_ops/scalar_get_s:.0f} ops/s")
        csv(f"{tag}.scalar_exists,{scalar_exists_s/n_ops*1e6:.2f},"
            f"{n_ops/scalar_exists_s:.0f} ops/s")

        for bs in batch_sizes:
            _clear_cache(b.db)
            t0 = time.perf_counter()
            for off in range(0, n_ops, bs):
                multi_get(b.db, [keys[i] for i in idx[off:off + bs]])
            g_s = time.perf_counter() - t0

            _clear_cache(b.db)
            t0 = time.perf_counter()
            for off in range(0, len(exists_probe), bs):
                multi_exists(b.db, exists_probe[off:off + bs])
            e_s = time.perf_counter() - t0

            sp_get = scalar_get_s / g_s
            sp_ex = scalar_exists_s / e_s
            speedups[name][bs] = sp_get
            csv(f"{tag}.multi_get.b{bs},{g_s/n_ops*1e6:.2f},"
                f"{n_ops/g_s:.0f} ops/s ({sp_get:.1f}x scalar)")
            csv(f"{tag}.multi_exists.b{bs},{e_s/len(exists_probe)*1e6:.2f},"
                f"{len(exists_probe)/e_s:.0f} ops/s ({sp_ex:.1f}x scalar)")
        b.close()
    return speedups


def run_sharded(n_keys: int = 24000, n_ops: int = 8192, value_size: int = 128,
                n_shards: int = 4, csv=print,
                batch_sizes=(256, 1024, 2048, 4096), repeats: int = 3) -> dict:
    """Shard-parallel ``multi_get``: ShardedTideDB(n_shards) vs one TideDB.

    Same key set, same batched probe sequence through both engines; reports
    ops/s per batch size (best of ``repeats`` passes — the minimum strips
    scheduler noise, which matters on small shared boxes) and the
    sharded/single speedup ratio.  The acceptance bar for the sharded front
    end is ≥1.5× at batch ≥1024; the fan-out needs real cores to win, so
    expect the ratio to degrade toward ~1× on 1–2-core machines.
    Returns ``{batch: speedup}``.
    """
    engines = {
        "single": Bench("tide-1", make_tide),
        "sharded": Bench(f"tide-x{n_shards}",
                         lambda p: make_tide_sharded(p, n_shards=n_shards)),
    }
    keys = gen_keys(n_keys, seed=23)
    idx = zipf_indices(n_keys, n_ops, 0.0, seed=29)
    times: dict = {name: {} for name in engines}
    for name, b in engines.items():
        b.fill(keys, value_size)
        for bs in batch_sizes:               # jit warm-up at every shape
            multi_get(b.db, [keys[i] for i in idx[:bs]])
        for bs in batch_sizes:
            best = float("inf")
            for _ in range(repeats):
                _clear_cache(b.db)
                t0 = time.perf_counter()
                for off in range(0, n_ops, bs):
                    multi_get(b.db, [keys[i] for i in idx[off:off + bs]])
                best = min(best, time.perf_counter() - t0)
            times[name][bs] = best
    speedups = {}
    for bs in batch_sizes:
        single_s, shard_s = times["single"][bs], times["sharded"][bs]
        speedups[bs] = single_s / shard_s
        csv(f"kvshard.v{value_size}.x1.multi_get.b{bs},"
            f"{single_s/n_ops*1e6:.2f},{n_ops/single_s:.0f} ops/s")
        csv(f"kvshard.v{value_size}.x{n_shards}.multi_get.b{bs},"
            f"{shard_s/n_ops*1e6:.2f},{n_ops/shard_s:.0f} ops/s "
            f"({speedups[bs]:.2f}x single)")
    for b in engines.values():
        b.close()
    return speedups
