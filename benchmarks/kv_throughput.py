"""Paper Figures 1/6/7/8: throughput vs value size × workload × skew.

Engines: tidehunter, rocksdb(sim), blobdb(sim).  Value sizes 64/128/1024 B;
workloads: 100% write, 50/50 mixed, 100% read (get + exists); skew θ∈{0,2}.
Reports ops/s and the engine write-amplification counters.
"""
from __future__ import annotations

import time

from .engines import ENGINES, Bench, gen_keys, zipf_indices


def run(n_keys: int = 6000, n_ops: int = 4000, csv=print) -> None:
    for value_size in (64, 128, 1024):
        keys = gen_keys(n_keys, seed=value_size)
        for theta in (0.0, 2.0):
            idx = zipf_indices(n_keys, n_ops, theta, seed=7)
            for name, factory in ENGINES.items():
                b = Bench(name, factory)
                fill_s = b.fill(keys, value_size)
                v = bytes(value_size)

                t0 = time.perf_counter()
                for j, i in enumerate(idx):
                    b.db.put(keys[i], v)
                w_s = time.perf_counter() - t0

                t0 = time.perf_counter()
                for j, i in enumerate(idx):
                    if j % 2 == 0:
                        b.db.get(keys[i])
                    else:
                        b.db.put(keys[i], v)
                m_s = time.perf_counter() - t0

                t0 = time.perf_counter()
                for i in idx:
                    b.db.get(keys[i])
                g_s = time.perf_counter() - t0

                t0 = time.perf_counter()
                for i in idx:
                    b.db.exists(keys[i])
                e_s = time.perf_counter() - t0

                stats = b.db.stats() if hasattr(b.db, "stats") else {}
                wa = 0.0
                if stats.get("bytes_written_app"):
                    wa = stats["bytes_written_disk"] / stats["bytes_written_app"]
                tag = f"kv.v{value_size}.t{int(theta)}.{name}"
                csv(f"{tag}.write,{w_s/n_ops*1e6:.2f},"
                    f"{n_ops/w_s:.0f} ops/s")
                csv(f"{tag}.mixed,{m_s/n_ops*1e6:.2f},{n_ops/m_s:.0f} ops/s")
                csv(f"{tag}.get,{g_s/n_ops*1e6:.2f},{n_ops/g_s:.0f} ops/s")
                csv(f"{tag}.exists,{e_s/n_ops*1e6:.2f},{n_ops/e_s:.0f} ops/s")
                csv(f"{tag}.write_amp,{wa:.2f},fill={fill_s:.1f}s")
                b.close()
