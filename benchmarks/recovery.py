"""Paper §3.3/§3.4: recovery time vs snapshot frequency.

More frequent snapshots shrink the WAL suffix that must be replayed; the
Control Region stays tiny because it stores positions, not index data.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

from repro.core.tidestore import DbConfig, KeyspaceConfig, TideDB
from repro.core.tidestore.wal import WalConfig

from .engines import gen_keys


def _cfg():
    return DbConfig(
        keyspaces=[KeyspaceConfig("default", n_cells=64,
                                  dirty_flush_threshold=100000)],
        wal=WalConfig(segment_size=4 * 1024 * 1024, background=False),
        index_wal=WalConfig(segment_size=32 * 1024 * 1024, background=False),
        background_snapshots=False,
    )


def run(n_keys: int = 20000, value_size: int = 256, csv=print) -> None:
    keys = gen_keys(n_keys, seed=11)
    for snap_every in (0, n_keys // 4, n_keys // 16):
        d = tempfile.mkdtemp(prefix="bench-recovery-")
        db = TideDB(d, _cfg())
        v = bytes(value_size)
        for i, k in enumerate(keys):
            db.put(k, v)
            if snap_every and i and i % snap_every == 0:
                db.snapshot_now(flush_threshold=1)
        # crash (no close): recovery must replay the suffix after the last
        # snapshot (or the whole WAL when snapshots are disabled)
        ctrl = os.path.join(d, "control.bin")
        ctrl_bytes = os.path.getsize(ctrl) if os.path.exists(ctrl) else 0
        t0 = time.perf_counter()
        db2 = TideDB(d, _cfg())
        recovery_s = time.perf_counter() - t0
        assert db2.get(keys[0]) == v and db2.get(keys[-1]) == v
        label = f"snap_every_{snap_every or 'never'}"
        csv(f"recovery.{label},{recovery_s*1e6:.0f},"
            f"{recovery_s*1e3:.1f} ms control_region={ctrl_bytes}B")
        db2.close()
        shutil.rmtree(d, ignore_errors=True)
