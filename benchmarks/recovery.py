"""Paper §3.3/§3.4: recovery time vs snapshot frequency.

More frequent snapshots shrink the WAL suffix that must be replayed; the
Control Region stays tiny because it stores positions, not index data.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

from repro.core.tidestore import (DbConfig, KeyspaceConfig, PruneOptions,
                                  TideDB)
from repro.core.tidestore.wal import WalConfig

from .engines import gen_keys


def _cfg():
    return DbConfig(
        keyspaces=[KeyspaceConfig("default", n_cells=64,
                                  dirty_flush_threshold=100000)],
        wal=WalConfig(segment_size=4 * 1024 * 1024, background=False),
        index_wal=WalConfig(segment_size=32 * 1024 * 1024, background=False),
        background_snapshots=False,
    )


def _prune_cfg():
    cfg = _cfg()
    cfg.wal = WalConfig(segment_size=32 * 1024, background=False)
    cfg.prune = PruneOptions(retain_epochs=2, min_reclaim_bytes=1 << 40)
    return cfg


def run(n_keys: int = 20000, value_size: int = 256, csv=print) -> None:
    keys = gen_keys(n_keys, seed=11)
    for snap_every in (0, n_keys // 4, n_keys // 16):
        d = tempfile.mkdtemp(prefix="bench-recovery-")
        db = TideDB(d, _cfg())
        v = bytes(value_size)
        for i, k in enumerate(keys):
            db.put(k, v)
            if snap_every and i and i % snap_every == 0:
                db.snapshot_now(flush_threshold=1)
        # crash (no close): recovery must replay the suffix after the last
        # snapshot (or the whole WAL when snapshots are disabled)
        ctrl = os.path.join(d, "control.bin")
        ctrl_bytes = os.path.getsize(ctrl) if os.path.exists(ctrl) else 0
        t0 = time.perf_counter()
        db2 = TideDB(d, _cfg())
        recovery_s = time.perf_counter() - t0
        assert db2.get(keys[0]) == v and db2.get(keys[-1]) == v
        label = f"snap_every_{snap_every or 'never'}"
        csv(f"recovery.{label},{recovery_s*1e6:.0f},"
            f"{recovery_s*1e3:.1f} ms control_region={ctrl_bytes}B")
        db2.close()
        shutil.rmtree(d, ignore_errors=True)


def run_smoke(csv=print) -> bool:
    """CI bound — correctness, not timing (timing flakes on a loaded
    1-core runner): recovery must survive (a) a crash with a mid-log hole
    left by epoch pruning, and (b) a torn Control Region, falling back to
    the rotated previous snapshot.  All retained keys must read back."""
    keys = gen_keys(800, seed=13)
    v = bytes(200)
    d = tempfile.mkdtemp(prefix="bench-recovery-smoke-")
    ok = True
    try:
        db = TideDB(d, _prune_cfg())
        for ep in (1, 2, 3, 4):
            db.put_many([(k, v) for k in keys[(ep - 1) * 200:ep * 200]],
                        epoch=ep)
            db.snapshot_now(flush_threshold=1)
        dropped = db.prune()["segments_pruned"]   # retires epochs 1-2
        ok &= dropped > 0
        # crash without close, then reopen across the mid-log hole
        db2 = TideDB(d, _prune_cfg())
        ok &= all(db2.get(k) == v for k in keys[400:])
        db2.close()
        # tear the Control Region; reopen must fall back to the rotation
        ctrl = os.path.join(d, "control.bin")
        with open(ctrl, "r+b") as f:
            f.truncate(os.path.getsize(ctrl) // 2)
        db3 = TideDB(d, _prune_cfg())
        ok &= all(db3.get(k) == v for k in keys[400:])
        db3.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)
    csv(f"recovery.smoke,0,{'ok' if ok else 'FAIL'} "
        f"(pruned_segments={dropped} torn-control fallback verified)")
    return bool(ok)


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="crash-recovery correctness gates: reopen across "
                         "pruned mid-log holes and a torn control region")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if run_smoke() else 1)
    run()
