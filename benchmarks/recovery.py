"""Paper §3.3/§3.4: recovery time vs snapshot frequency.

More frequent snapshots shrink the WAL suffix that must be replayed; the
Control Region stays tiny because it stores positions, not index data.

Emits ``BENCH_recovery.json`` so cold-start cost records across PRs.
Schema (``recovery/v1``)::

    {
      "schema": "recovery/v1",
      "engine": "tidehunter",
      "n_keys": 20000,
      "results": [
        {"case": "snapshot_sweep", "snap_every": 1250,   # 0 = never
         "recovery_s": 0.31, "control_region_bytes": 412},
        {"case": "filter_probe", "persist_filters": true,
         "reopen_s": 0.02, "probe_s": 0.004,
         "filters_loaded": 18, "filters_rebuilt": 0},
        ...
      ]
    }

The ``filter_probe`` rows time the persisted-Bloom fast path: reopen plus
a cold miss-probe with filters persisted at flush vs lazily rebuilt from
the index blobs — the cost the T_FILTER record exists to delete.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

from repro.core.tidestore import (DbConfig, KeyspaceConfig, PruneOptions,
                                  TideDB)
from repro.core.tidestore.wal import WalConfig

from .engines import gen_keys


def _cfg():
    return DbConfig(
        keyspaces=[KeyspaceConfig("default", n_cells=64,
                                  dirty_flush_threshold=100000)],
        wal=WalConfig(segment_size=4 * 1024 * 1024, background=False),
        index_wal=WalConfig(segment_size=32 * 1024 * 1024, background=False),
        background_snapshots=False,
    )


def _prune_cfg():
    cfg = _cfg()
    cfg.wal = WalConfig(segment_size=32 * 1024, background=False)
    cfg.prune = PruneOptions(retain_epochs=2, min_reclaim_bytes=1 << 40)
    return cfg


def _filter_cfg(persist: bool):
    cfg = _cfg()
    cfg.keyspaces = [KeyspaceConfig("default", n_cells=64,
                                    dirty_flush_threshold=64)]
    cfg.persist_filters = persist
    cfg.blob_cache_bytes = 0
    return cfg


def run_filter_probe(n_keys: int = 8000, value_size: int = 256, csv=print,
                     results: list | None = None) -> dict:
    """Persisted-filter fast path: reopen + cold miss-probe with filters
    persisted at flush vs lazily rebuilt from index blobs.  Returns
    ``{persist: (reopen_s, probe_s)}``."""
    keys = gen_keys(n_keys, seed=17)
    misses = gen_keys(n_keys // 4, seed=18)
    v = bytes(value_size)
    out: dict = {}
    for persist in (True, False):
        d = tempfile.mkdtemp(prefix="bench-recovery-filters-")
        try:
            db = TideDB(d, _filter_cfg(persist))
            db.put_many([(k, v) for k in keys])
            db.snapshot_now(flush_threshold=1)
            db.close()
            t0 = time.perf_counter()
            db2 = TideDB(d, _filter_cfg(persist))
            reopen_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            assert not any(db2.multi_exists(misses))
            probe_s = time.perf_counter() - t0
            loaded = db2.metrics.bloom_filters_loaded
            rebuilt = db2.metrics.bloom_lazy_rebuilds
            db2.close()
        finally:
            shutil.rmtree(d, ignore_errors=True)
        out[persist] = (reopen_s, probe_s)
        if results is not None:
            results.append({"case": "filter_probe",
                            "persist_filters": persist,
                            "reopen_s": reopen_s, "probe_s": probe_s,
                            "filters_loaded": loaded,
                            "filters_rebuilt": rebuilt})
        tag = "persisted" if persist else "rebuilt"
        csv(f"recovery.filters_{tag},{probe_s*1e6:.0f},"
            f"probe {probe_s*1e3:.1f} ms reopen {reopen_s*1e3:.1f} ms "
            f"(loaded={loaded} rebuilt={rebuilt})")
    return out


def run(n_keys: int = 20000, value_size: int = 256, csv=print,
        json_path: str | None = "BENCH_recovery.json") -> None:
    results: list[dict] = []
    keys = gen_keys(n_keys, seed=11)
    for snap_every in (0, n_keys // 4, n_keys // 16):
        d = tempfile.mkdtemp(prefix="bench-recovery-")
        db = TideDB(d, _cfg())
        v = bytes(value_size)
        for i, k in enumerate(keys):
            db.put(k, v)
            if snap_every and i and i % snap_every == 0:
                db.snapshot_now(flush_threshold=1)
        # crash (no close): recovery must replay the suffix after the last
        # snapshot (or the whole WAL when snapshots are disabled)
        ctrl = os.path.join(d, "control.bin")
        ctrl_bytes = os.path.getsize(ctrl) if os.path.exists(ctrl) else 0
        t0 = time.perf_counter()
        db2 = TideDB(d, _cfg())
        recovery_s = time.perf_counter() - t0
        assert db2.get(keys[0]) == v and db2.get(keys[-1]) == v
        label = f"snap_every_{snap_every or 'never'}"
        csv(f"recovery.{label},{recovery_s*1e6:.0f},"
            f"{recovery_s*1e3:.1f} ms control_region={ctrl_bytes}B")
        results.append({"case": "snapshot_sweep", "snap_every": snap_every,
                        "recovery_s": recovery_s,
                        "control_region_bytes": ctrl_bytes})
        db2.close()
        shutil.rmtree(d, ignore_errors=True)

    run_filter_probe(csv=csv, results=results)

    if json_path:
        with open(json_path, "w") as f:
            json.dump({"schema": "recovery/v1", "engine": "tidehunter",
                       "n_keys": n_keys, "results": results}, f, indent=1)
        csv(f"recovery.json,0,{json_path}")


def run_smoke(csv=print) -> bool:
    """CI bound — correctness, not timing (timing flakes on a loaded
    1-core runner): recovery must survive (a) a crash with a mid-log hole
    left by epoch pruning, and (b) a torn Control Region, falling back to
    the rotated previous snapshot.  All retained keys must read back."""
    keys = gen_keys(800, seed=13)
    v = bytes(200)
    d = tempfile.mkdtemp(prefix="bench-recovery-smoke-")
    ok = True
    try:
        db = TideDB(d, _prune_cfg())
        for ep in (1, 2, 3, 4):
            db.put_many([(k, v) for k in keys[(ep - 1) * 200:ep * 200]],
                        epoch=ep)
            db.snapshot_now(flush_threshold=1)
        dropped = db.prune()["segments_pruned"]   # retires epochs 1-2
        ok &= dropped > 0
        # crash without close, then reopen across the mid-log hole
        db2 = TideDB(d, _prune_cfg())
        ok &= all(db2.get(k) == v for k in keys[400:])
        db2.close()
        # tear the Control Region; reopen must fall back to the rotation
        ctrl = os.path.join(d, "control.bin")
        with open(ctrl, "r+b") as f:
            f.truncate(os.path.getsize(ctrl) // 2)
        db3 = TideDB(d, _prune_cfg())
        ok &= all(db3.get(k) == v for k in keys[400:])
        db3.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)
    csv(f"recovery.smoke,0,{'ok' if ok else 'FAIL'} "
        f"(pruned_segments={dropped} torn-control fallback verified)")
    return bool(ok)


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="crash-recovery correctness gates: reopen across "
                         "pruned mid-log holes and a torn control region")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if run_smoke() else 1)
    run()
