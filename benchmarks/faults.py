"""Fault-schedule fuzz + integrity gates (robustness tier).

Five correctness gates, no timing targets:

1. **Durability fuzz** — N seeded random fault schedules (``FaultyIo``
   injecting EIO / ENOSPC / short / torn writes / latency into the WAL's
   pwrite/pwritev/fsync call stream) drive a mixed workload of puts,
   deletes, sync flushes, and relocation slices.  After a simulated crash
   (``db.crash()``) and a clean reopen, every sync-acknowledged write must
   read back as its acknowledged-or-later version and no reader may ever
   observe a torn value.
2. **Scrub detection** — corruptions planted at known sealed-segment
   positions while the store is open must ALL be found (and quarantined)
   by one ``db.scrub()`` pass: detection rate 1.0, no false positives.
3. **Degraded serving** — a disk that fills mid-batch must flip the store
   to read-only degraded mode; ``KvBatchServer`` then sheds writes via
   ``Overloaded`` while continuing to serve reads/exists for everything
   that landed.
4. **Crash-schedule exploration** — where the fuzz tier samples, the
   explorer (``tidestore.simulate``) is systematic: each seeded trace is
   crashed at EVERY injectable I/O call it reaches (meta-checked — fork k
   must report ``crashed_at == k``), reopened, and verified against the
   ``ShadowModel`` durability oracle.  Sharded traces give one shard an
   ENOSPC schedule and additionally gate ``try_recover``: degraded forks
   must refuse to clear on a still-failing device and must exit degraded
   mode once it heals.  Replicated repair traces crash mid-repair and
   mid-resync and hold the same oracle with ZERO reads lost (the
   surviving replica answers through the blackout).
5. **Self-healing repair** — under ``replication=2``, corruptions planted
   on one replica's sealed segments must ALL be detected by one scrub
   pass AND all be repaired from the healthy peer: while the repair
   drains in bounded slices, every user read (the whole keyspace, every
   slice boundary) must return the correct value — zero reads lost — and
   afterwards the damaged shard must serve every planted key directly
   with failover disabled, with both quarantines empty.

Emits ``BENCH_faults.json`` (schema ``faults/v3``)::

    {
      "schema": "faults/v3",
      "fuzz": {"examples": 200, "violations": 0, "acked_total": ...,
               "degraded_runs": ..., "injected": {"eio": ..., ...}},
      "scrub": {"planted": ..., "found": ..., "false_positives": 0,
                "detection_rate": 1.0},
      "degraded_serving": {"degraded": true, "reads_served": ...,
                           "writes_shed": ..., "writes_failed": ...},
      "repair": {"planted": ..., "detected": ..., "repaired": ...,
                 "detection_rate": 1.0, "repair_rate": 1.0,
                 "reads_during_repair": ..., "reads_lost": 0,
                 "verified_direct": ..., "quarantined_after": 0},
      "explorer": {"traces": 25, "fault_points": ..., "forks": ...,
                   "violations": 0, "unreached_points": 0,
                   "styles": {"clean": ..., "torn": ...},
                   "sharded": {"traces": 8, "fault_points": ...,
                               "degraded_forks": ..., "recovered": ...,
                               "stayed_degraded": ...},
                   "repair_traces": {"traces": 2, "fault_points": ...,
                                     "forks": ..., "violations": 0,
                                     "lost_reads": 0}}
    }

``python -m benchmarks.faults --smoke`` runs all five gates (``--seeds N``
resizes the fuzz tier) and exits non-zero unless the invariant held on
every schedule, the scrubber found 100% of planted corruptions, the
degraded store kept serving reads, repair restored 100% of planted
corruptions with zero reads lost, and the explorer found zero oracle
violations at full fault-point coverage.  ``--smoke-explorer`` runs only a
bounded fixed-seed explorer pass (CI budget: well under a minute) and
prints the explored fault-point count.  ``--smoke-repair`` runs only the
replicated repair gate plus one bounded repair-trace exploration.
"""
from __future__ import annotations

import hashlib
import json
import os
import random
import shutil
import tempfile

from repro.core.tidestore import (DbConfig, DegradedError, FaultRule,
                                  FaultyIo, KeyspaceConfig, ReadOptions,
                                  ShardedTideDB, TideDB, random_schedule)
from repro.core.tidestore.wal import HEADER_SIZE, WalConfig, _ENTRY_HDR


def _cfg(io=None, cache_bytes=1 * 1024 * 1024):
    return DbConfig(
        keyspaces=[KeyspaceConfig("default", n_cells=16,
                                  dirty_flush_threshold=64)],
        wal=WalConfig(segment_size=16 * 1024, background=False),
        index_wal=WalConfig(segment_size=1 * 1024 * 1024, background=False),
        background_snapshots=False,
        cache_bytes=cache_bytes,
        copy_threads=0,              # in-line copies: deterministic fault order
        io=io,
    )


def _keys(n, tag=""):
    return [hashlib.sha256(f"{tag}{i}".encode()).digest() for i in range(n)]


# ------------------------------------------------------------------ gate 1
def _fuzz_one(seed: int, n_ops: int = 60, n_keys: int = 24) -> dict:
    """One seeded schedule through put/delete/flush/prune; crash; verify.

    Ack bookkeeping: a successful ``db.flush()`` acknowledges every version
    written so far.  Post-crash the replayed value for a key must be one of
    the versions written at-or-after its last acknowledged version (the ack
    is durable; a later non-acked write may legally have landed in full) —
    anything else is a lost ack or a torn read."""
    rules = random_schedule(seed)
    io = FaultyIo(rules, seed=seed)
    keys = _keys(n_keys, f"fz{seed}")
    rng = random.Random(seed ^ 0x5EED)
    d = tempfile.mkdtemp(prefix="bench-faults-")
    violations = []
    try:
        db = TideDB(d, _cfg(io=io))
        history = {k: [] for k in keys}      # key -> [(op_idx, value|None)]
        last_ack = {}                        # key -> op_idx of last acked ver
        acked = 0
        degraded = False
        for i in range(n_ops):
            k = keys[rng.randrange(n_keys)]
            roll = rng.random()
            try:
                if roll < 0.60:
                    v = b"s%d-op%d" % (seed, i)
                    db.put(k, v)
                    history[k].append((i, v))
                elif roll < 0.75:
                    db.delete(k)
                    history[k].append((i, None))
                elif roll < 0.90:
                    db.flush()               # ack point for ALL prior writes
                    acked += 1
                    for kk, h in history.items():
                        if h:
                            last_ack[kk] = h[-1][0]
                else:
                    db.prune_step()          # relocation under faults
            except DegradedError:
                degraded = True
                break
            except OSError:
                continue                     # failed op: fate unknown
        degraded = degraded or db.degraded
        db.crash()

        db2 = TideDB(d, _cfg())              # clean I/O for verification
        try:
            for k in keys:
                got = db2.get(k)
                h = history[k]
                if k in last_ack:
                    valid = {v for idx, v in h if idx >= last_ack[k]}
                else:
                    valid = {v for _, v in h} | {None}
                if got not in valid:
                    violations.append(
                        {"seed": seed, "key": k.hex()[:12],
                         "got": repr(got)[:40],
                         "acked_at": last_ack.get(k)})
        finally:
            db2.close()
        return {"seed": seed, "violations": violations,
                "acked_flushes": acked, "degraded": degraded,
                "injected": io.injected_counts()}
    finally:
        shutil.rmtree(d, ignore_errors=True)


def _run_fuzz(n_seeds: int, csv) -> dict:
    total_inj: dict = {}
    violations = []
    acked_total = 0
    degraded_runs = 0
    for seed in range(n_seeds):
        r = _fuzz_one(seed)
        violations.extend(r["violations"])
        acked_total += r["acked_flushes"]
        degraded_runs += int(r["degraded"])
        for kind, n in r["injected"].items():
            total_inj[kind] = total_inj.get(kind, 0) + n
    out = {"examples": n_seeds, "violations": len(violations),
           "violation_detail": violations[:5],
           "acked_total": acked_total, "degraded_runs": degraded_runs,
           "injected": total_inj}
    csv(f"faults.fuzz,0,{n_seeds} schedules violations={len(violations)} "
        f"acked={acked_total} degraded_runs={degraded_runs} "
        f"injected={sum(total_inj.values())} {total_inj}")
    return out


# ------------------------------------------------------------------ gate 2
def _run_scrub_detection(n_corruptions: int = 8, n_keys: int = 600,
                         csv=print) -> dict:
    d = tempfile.mkdtemp(prefix="bench-scrub-")
    try:
        db = TideDB(d, _cfg(cache_bytes=0))
        keys = _keys(n_keys, "scrub")
        pos = [db.put(k, b"p" * 150) for k in keys]
        db.flush()
        wal = db.value_wal
        seg_size = wal.cfg.segment_size
        tail_seg = wal.tail // seg_size
        sealed = [p for p in pos if p // seg_size < tail_seg]
        rng = random.Random(42)
        planted = sorted(rng.sample(sealed, n_corruptions))
        for p in planted:
            fd = wal._fd(p // seg_size)
            off = p % seg_size + HEADER_SIZE + 3
            old = os.pread(fd, 1, off)
            os.pwrite(fd, bytes([old[0] ^ 0xFF]), off)
        rep = db.scrub()
        found = sorted(f["pos"] for f in rep["findings"]
                       if f["kind"] == "crc")
        false_pos = len(set(found) - set(planted))
        quarantined = set(wal.quarantined())
        db.close()
        out = {"planted": len(planted), "found": len(set(found)),
               "false_positives": false_pos,
               "all_quarantined": set(planted) <= quarantined,
               "detection_rate": len(set(found) & set(planted))
                                 / len(planted)}
        csv(f"faults.scrub,0,detection {out['found']}/{out['planted']} "
            f"rate={out['detection_rate']:.2f} "
            f"false_positives={false_pos}")
        return out
    finally:
        shutil.rmtree(d, ignore_errors=True)


# ------------------------------------------------------------------ gate 3
def _run_degraded_serving(csv=print) -> dict:
    from repro.serving.admission import Overloaded
    from repro.serving.engine import KvBatchServer
    d = tempfile.mkdtemp(prefix="bench-degraded-")
    try:
        # The disk "fills up" after a few payload copies and stays full
        # (count=None); poison-header repairs fail the same way.  A small
        # max_batch splits the submissions into many write stages, so the
        # failure lands mid-run: some stages are durably served, then the
        # store degrades under live traffic.
        io = FaultyIo([
            FaultRule(op="pwritev", kind="enospc", after=8, count=None),
            FaultRule(op="pwrite", kind="enospc", after=8, count=None),
        ])
        db = TideDB(d, _cfg(io=io))
        srv = KvBatchServer(db, max_batch=16)
        keys = _keys(128, "deg")
        writes, shed = [], 0
        for k in keys:
            try:
                writes.append((k, srv.submit_put(k, b"v" * 100)))
            except Overloaded:
                shed += 1
            srv.step()
        while srv.step():
            pass
        landed = [k for k, w in writes if w.error is None]
        failed = len(writes) - len(landed)
        try:
            srv.submit_put(keys[0], b"post-degrade")
        except Overloaded:
            shed += 1
        gets = [srv.submit_get(k) for k in landed]
        ex = [srv.submit_exists(k) for k in landed[:16]]
        while srv.step():
            pass
        reads_served = sum(1 for k, g in zip(landed, gets)
                           if g.error is None and g.result() == b"v" * 100)
        exists_served = sum(1 for e in ex if e.error is None and e.result())
        out = {"degraded": db.degraded,
               "reason": db.degraded_reason or "",
               "writes_landed": len(landed), "writes_failed": failed,
               "writes_shed": shed,
               "reads_served": reads_served,
               "reads_expected": len(landed),
               "exists_served": exists_served}
        db.crash()
        csv(f"faults.degraded,0,degraded={out['degraded']} "
            f"landed={out['writes_landed']} failed={failed} "
            f"shed={out['writes_shed']} reads={reads_served}/{len(landed)}")
        return out
    finally:
        shutil.rmtree(d, ignore_errors=True)


# ------------------------------------------------------------------ gate 4
def _run_repair(n_corruptions: int = 8, n_keys: int = 600,
                csv=print) -> dict:
    """Self-healing gate: plant corruptions on ONE replica of an R=2
    store; scrub must find them all, ``RepairController`` must restore a
    healthy copy onto the damaged shard from its peer, and no user read
    may return a wrong answer at any point — before, during (between
    bounded repair slices), or after the repair."""
    d = tempfile.mkdtemp(prefix="bench-repair-")
    no_failover = ReadOptions(strict_errors=True, fill_cache=False)
    try:
        sdb = ShardedTideDB(d, _cfg(cache_bytes=0), n_shards=2,
                            replication=2)
        keys = _keys(n_keys, "repair")
        expect = {k: b"r" + k[:8] + b"%06d" % i
                  for i, k in enumerate(keys)}
        sdb.put_many(list(expect.items()))
        sdb.flush()
        damaged = sdb.shards[0]
        wal = damaged.value_wal
        seg_size = wal.cfg.segment_size
        tail_seg = wal.tail // seg_size
        # Every key is replicated onto shard 0; plant only in sealed
        # segments (the scrubber's coverage) and only in the VALUE region,
        # past the entry header and key bytes — replay and repair
        # identification still see the true key, like real bitrot in a
        # large value.
        sealed = [k for k in keys
                  if damaged.table.get_position(0, k) // seg_size
                  < tail_seg]
        rng = random.Random(42)
        victims = rng.sample(sealed, n_corruptions)
        planted = {}
        for k in victims:
            p = damaged.table.get_position(0, k)
            fd = wal._fd(p // seg_size)
            off = p % seg_size + HEADER_SIZE + _ENTRY_HDR.size + len(k) + 1
            old = os.pread(fd, 1, off)
            os.pwrite(fd, bytes([old[0] ^ 0x5A]), off)
            planted[k] = p
        sdb.clear_caches()

        rep = sdb.scrub()
        found = {f["pos"] for f in rep["findings"]
                 if f["kind"] == "crc" and f["shard"] == 0}
        detected = len(found & set(planted.values()))
        false_pos = len(found - set(planted.values()))

        # Drain the quarantine in bounded slices; between every slice the
        # WHOLE keyspace must read back correctly through the store's
        # public read path (failover covers what repair hasn't reached).
        all_keys = list(keys)
        want = [expect[k] for k in all_keys]
        reads, lost = 0, 0
        outcomes = {"examined": 0, "repaired": 0, "cas_lost": 0,
                    "unrepaired": 0, "skipped": 0}

        def sweep():
            nonlocal reads, lost
            got = sdb.multi_get(all_keys)
            reads += len(all_keys)
            lost += sum(1 for g, w in zip(got, want) if g != w)

        sweep()                                  # during the damage window
        while True:
            step = sdb.repair_step(max_repairs=2)
            for key_, n in step.items():
                outcomes[key_] += n
            sweep()                              # mid-repair reads
            if step["examined"] == 0:
                break

        # Post-repair: the damaged shard serves every planted key
        # DIRECTLY, failover disabled, and both quarantines are empty.
        sdb.clear_caches()
        verified = 0
        for k in planted:
            try:
                if damaged.get(k, opts=no_failover) == expect[k]:
                    verified += 1
            except KeyError:
                pass
        quarantined_after = sum(len(sh.value_wal.quarantined())
                                for sh in sdb.shards)
        sdb.close()
        out = {"planted": len(planted), "detected": detected,
               "false_positives": false_pos,
               "detection_rate": detected / len(planted),
               "repaired": outcomes["repaired"],
               "repair_rate": verified / len(planted),
               "outcomes": outcomes,
               "reads_during_repair": reads, "reads_lost": lost,
               "verified_direct": verified,
               "quarantined_after": quarantined_after}
        csv(f"faults.repair,0,detected {detected}/{len(planted)} "
            f"repaired={verified}/{len(planted)} "
            f"reads={reads} lost={lost} "
            f"quarantined_after={quarantined_after}")
        return out
    finally:
        shutil.rmtree(d, ignore_errors=True)


def _repair_ok(rp: dict) -> bool:
    return (rp["detection_rate"] == 1.0 and rp["false_positives"] == 0
            and rp["repair_rate"] == 1.0 and rp["reads_lost"] == 0
            and rp["reads_during_repair"] > 0
            and rp["quarantined_after"] == 0)


# ------------------------------------------------------------------ gate 5
def _run_explorer(n_traces: int = 25, n_sharded: int = 8, csv=print,
                  n_ops: int = 18, sharded_ops: int = 12,
                  n_repair: int = 2, repair_points: int = 12) -> dict:
    """Systematic crash-schedule exploration (``tidestore.simulate``).

    Every seeded trace is crashed at EVERY injectable I/O call it reaches
    — the meta-check is ``fork_points == range(fault_points)``: fork k
    really died at fault point k, so no point was silently skipped or
    swallowed.  Sharded traces run shard 0 under an ENOSPC schedule and
    gate the ``try_recover`` contract on every degraded fork.  Repair
    traces (replicated, R=2) plant corruption, scrub, repair, degrade,
    and resync — crashing inside the repair pass and inside the resync
    (meta-checked via ``phase_spans``) — and additionally require that no
    mid-trace read was lost: the surviving replica answers through the
    crash blackout."""
    from repro.core.tidestore.simulate import (explore_repair_trace,
                                               explore_sharded_trace,
                                               explore_trace)
    out = {
        "traces": n_traces, "fault_points": 0, "forks": 0,
        "violations": 0, "violation_detail": [],
        "unreached_points": 0, "schedule_mismatches": 0,
        "styles": {},
        "sharded": {"traces": n_sharded, "fault_points": 0, "forks": 0,
                    "degraded_forks": 0, "recovered": 0,
                    "stayed_degraded": 0, "violations": 0},
        "repair_traces": {"traces": n_repair, "fault_points": 0,
                          "forks": 0, "violations": 0, "lost_reads": 0,
                          "phase_misses": 0},
    }
    for seed in range(n_traces):
        rep = explore_trace(seed, n_ops=n_ops)
        out["fault_points"] += rep["fault_points"]
        out["forks"] += rep["forks"]
        out["violations"] += len(rep["violations"])
        out["violation_detail"].extend(rep["violations"][:3])
        out["unreached_points"] += len(rep["unreached_points"])
        if rep["fork_points"] != list(range(rep["fault_points"])):
            out["schedule_mismatches"] += 1
        for style, n in rep["style_counts"].items():
            out["styles"][style] = out["styles"].get(style, 0) + n
    sh = out["sharded"]
    for seed in range(n_sharded):
        rep = explore_sharded_trace(seed, n_ops=sharded_ops)
        sh["fault_points"] += rep["fault_points"]
        sh["forks"] += rep["forks"]
        sh["degraded_forks"] += rep["degraded_forks"]
        sh["recovered"] += rep["recovered"]
        sh["stayed_degraded"] += rep["stayed_degraded"]
        sh["violations"] += len(rep["violations"])
        out["violation_detail"].extend(rep["violations"][:3])
        if rep["fork_points"] != list(range(rep["fault_points"])):
            out["schedule_mismatches"] += 1
    rt = out["repair_traces"]
    for seed in range(n_repair):
        rep = explore_repair_trace(seed, max_points=repair_points)
        rt["fault_points"] += rep["fault_points"]
        rt["forks"] += rep["forks"]
        rt["violations"] += len(rep["violations"])
        rt["lost_reads"] += rep["lost_reads"]
        out["violation_detail"].extend(rep["violations"][:3])
        # Meta-check: the trace's repair pass AND its post-recover resync
        # both performed injectable I/O — crash-during-repair and
        # crash-during-resync were genuinely explorable.
        for phase in ("repair", "recover"):
            lo, hi = rep["phase_spans"].get(phase, (0, 0))
            if hi <= lo:
                rt["phase_misses"] += 1
    out["violation_detail"] = out["violation_detail"][:5]
    csv(f"faults.explorer,0,{n_traces} traces fault_points="
        f"{out['fault_points']} forks={out['forks']} "
        f"violations={out['violations']} "
        f"unreached={out['unreached_points']} styles={out['styles']}")
    csv(f"faults.explorer.sharded,0,{n_sharded} traces fault_points="
        f"{sh['fault_points']} degraded={sh['degraded_forks']} "
        f"recovered={sh['recovered']} "
        f"stayed_degraded={sh['stayed_degraded']} "
        f"violations={sh['violations']}")
    csv(f"faults.explorer.repair,0,{n_repair} traces fault_points="
        f"{rt['fault_points']} forks={rt['forks']} "
        f"violations={rt['violations']} lost_reads={rt['lost_reads']} "
        f"phase_misses={rt['phase_misses']}")
    return out


def _explorer_ok(ex: dict) -> bool:
    sh = ex["sharded"]
    rt = ex["repair_traces"]
    return (ex["violations"] == 0 and sh["violations"] == 0
            and ex["unreached_points"] == 0
            and ex["schedule_mismatches"] == 0
            and ex["fault_points"] > 0
            and ex["forks"] == ex["fault_points"]
            and len(ex["styles"]) >= 2
            and sh["degraded_forks"] > 0
            and sh["recovered"] == sh["degraded_forks"]
            and rt["violations"] == 0 and rt["lost_reads"] == 0
            and rt["phase_misses"] == 0
            and (rt["traces"] == 0 or rt["forks"] > 0))


# ---------------------------------------------------------------- harness
def run(n_seeds: int = 200, csv=print,
        json_path: str | None = "BENCH_faults.json",
        explorer_traces: int = 25, explorer_sharded: int = 8) -> dict:
    report = {
        "schema": "faults/v3",
        "fuzz": _run_fuzz(n_seeds, csv),
        "scrub": _run_scrub_detection(csv=csv),
        "degraded_serving": _run_degraded_serving(csv=csv),
        "repair": _run_repair(csv=csv),
        "explorer": _run_explorer(n_traces=explorer_traces,
                                  n_sharded=explorer_sharded, csv=csv),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
        csv(f"faults.json,0,{json_path}")
    return report


def run_smoke(csv=print, n_seeds: int = 200) -> bool:
    """CI gates: durability invariant on every schedule, 100% scrub
    detection with zero false positives, a full disk leaves a
    read-serving (write-shedding) store, replicated repair restores every
    planted corruption without losing a read, and the crash-schedule
    explorer holds the oracle at every reachable fault point."""
    report = run(n_seeds=n_seeds, csv=csv, json_path="BENCH_faults.json")
    fz, sc, dg = (report["fuzz"], report["scrub"],
                  report["degraded_serving"])
    invariant = fz["violations"] == 0 and fz["acked_total"] > 0 \
        and sum(fz["injected"].values()) > 0
    detection = (sc["detection_rate"] == 1.0 and sc["false_positives"] == 0
                 and sc["all_quarantined"])
    serving = (dg["degraded"] and dg["writes_shed"] > 0
               and dg["reads_served"] == dg["reads_expected"]
               and dg["reads_served"] > 0)
    repair = _repair_ok(report["repair"])
    explorer = _explorer_ok(report["explorer"])
    ok = invariant and detection and serving and repair and explorer
    csv(f"faults.smoke,0,{'ok' if ok else 'FAIL'} "
        f"(invariant={invariant} detection={detection} serving={serving} "
        f"repair={repair} explorer={explorer})")
    return ok


def run_smoke_explorer(csv=print, n_traces: int = 3,
                       n_sharded: int = 1) -> bool:
    """Bounded explorer-only CI gate: a fixed small seed set, reduced
    trace length, still crashing at EVERY reachable fault point.  Prints
    the explored fault-point count; well under a minute."""
    ex = _run_explorer(n_traces=n_traces, n_sharded=n_sharded, csv=csv,
                       n_ops=10, sharded_ops=10)
    ok = _explorer_ok(ex)
    csv(f"faults.smoke_explorer,0,{'ok' if ok else 'FAIL'} "
        f"fault_points_explored="
        f"{ex['fault_points'] + ex['sharded']['fault_points']} "
        f"(violations={ex['violations'] + ex['sharded']['violations']} "
        f"unreached={ex['unreached_points']} "
        f"recovered={ex['sharded']['recovered']}"
        f"/{ex['sharded']['degraded_forks']})")
    return ok


def run_smoke_repair(csv=print) -> bool:
    """Bounded repair-only CI gate: the replicated repair gate (planted
    corruptions on one replica of an R=2 store: 100% detected AND
    repaired, zero reads lost during the repair window) plus one
    fixed-seed repair-trace exploration crashing inside the repair pass
    and the resync."""
    from repro.core.tidestore.simulate import explore_repair_trace
    rp = _run_repair(csv=csv)
    trace = explore_repair_trace(0, max_points=10)
    spans_ok = all(hi > lo for lo, hi in
                   (trace["phase_spans"].get(p, (0, 0))
                    for p in ("repair", "recover")))
    ok = (_repair_ok(rp) and trace["violations"] == []
          and trace["lost_reads"] == 0 and trace["forks"] > 0
          and spans_ok)
    csv(f"faults.smoke_repair,0,{'ok' if ok else 'FAIL'} "
        f"repaired={rp['verified_direct']}/{rp['planted']} "
        f"reads_lost={rp['reads_lost']} "
        f"trace_forks={trace['forks']} "
        f"trace_violations={len(trace['violations'])} "
        f"trace_lost_reads={trace['lost_reads']}")
    return ok


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seeded fault schedules + scrub detection + "
                         "degraded serving + crash-schedule explorer; "
                         "exit 1 unless every acknowledged write survived "
                         "crash+reopen, all planted corruptions were "
                         "found, the degraded store kept serving reads, "
                         "and the explorer held the durability oracle at "
                         "every reachable fault point")
    ap.add_argument("--smoke-explorer", action="store_true",
                    help="bounded explorer-only gate: fixed seeds, every "
                         "fault point, prints the explored fault-point "
                         "count; exits 1 on any oracle violation or "
                         "unreached point")
    ap.add_argument("--smoke-repair", action="store_true",
                    help="bounded repair-only gate: planted corruptions "
                         "on one replica of an R=2 store must be 100%% "
                         "detected and repaired with zero reads lost, "
                         "and a repair-bearing crash trace must hold the "
                         "durability oracle; exits 1 otherwise")
    ap.add_argument("--seeds", type=int, default=200, metavar="N",
                    help="fuzz-schedule seed count for the full run / "
                         "--smoke (default: 200)")
    args = ap.parse_args()
    if args.smoke_explorer:
        sys.exit(0 if run_smoke_explorer() else 1)
    if args.smoke_repair:
        sys.exit(0 if run_smoke_repair() else 1)
    if args.smoke:
        sys.exit(0 if run_smoke(n_seeds=args.seeds) else 1)
    run(n_seeds=args.seeds)
