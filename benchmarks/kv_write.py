"""kvwrite suite: the vectorized write pipeline's throughput trajectory.

Measures scalar ``put`` vs ``put_many`` vs batched ``write_batch`` on a
fresh store per mode (insert workloads stay comparable), across value
sizes 128 B–16 KB and batch sizes, on the async-durability path, plus a
small sync-durability probe (where batching amortizes the fsync, not just
the allocation lock).  Acceptance bar: ``put_many``/``write_batch`` ≥ 5×
scalar ``put`` at batch ≥ 256 with 1 KB values, async durability.

Emits ``BENCH_kvwrite.json`` so the write-perf trajectory records across
PRs.  Schema (``kvwrite/v1``)::

    {
      "schema": "kvwrite/v1",
      "engine": "tidehunter",
      "n_ops": 4096,
      "results": [
        {"mode": "scalar|put_many|write_batch",
         "value_size": 1024,            # bytes per value
         "batch": 256,                  # 1 for scalar
         "durability": "async|sync",
         "us_per_op": 12.3,
         "ops_per_s": 81000.0,
         "speedup_vs_scalar": 6.8},     # vs same (value_size, durability)
        ...
      ]
    }

``python -m benchmarks.kv_write --smoke`` runs a tiny configuration and
exits non-zero unless batched ≥ scalar throughput — a CI sanity bound on
the pipeline's shape, deliberately far below the 5× acceptance bar so it
never flakes on loaded runners.
"""
from __future__ import annotations

import json
import time

from .engines import Bench, gen_keys, make_tide

VALUE_SIZES = (128, 1024, 16384)
BATCH_SIZES = (64, 256, 1024)


def _fresh(factory):
    return Bench("tidehunter", factory)


def _time_scalar(factory, keys, value, opts) -> float:
    b = _fresh(factory)
    t0 = time.perf_counter()
    if opts is None:
        for k in keys:
            b.db.put(k, value)
    else:
        for k in keys:
            b.db.put(k, value, opts=opts)
    dt = time.perf_counter() - t0
    b.close()
    return dt


def _time_put_many(factory, keys, value, bs, opts) -> float:
    b = _fresh(factory)
    t0 = time.perf_counter()
    for off in range(0, len(keys), bs):
        b.db.put_many([(k, value) for k in keys[off:off + bs]], opts=opts)
    dt = time.perf_counter() - t0
    b.close()
    return dt


def _time_write_batch(factory, keys, value, bs, opts) -> float:
    from repro.core.tidestore.api import WriteBatch
    b = _fresh(factory)
    t0 = time.perf_counter()
    for off in range(0, len(keys), bs):
        wb = WriteBatch()
        for k in keys[off:off + bs]:
            wb.put(k, value)
        b.db.write_batch(wb, opts=opts)
    dt = time.perf_counter() - t0
    b.close()
    return dt


def run(n_ops: int = 4096, value_sizes=VALUE_SIZES, batch_sizes=BATCH_SIZES,
        sync_probe: bool = True, sync_ops: int = 192, csv=print,
        json_path: str | None = "BENCH_kvwrite.json",
        factory=make_tide) -> dict:
    """Returns ``{(value_size, durability): {mode: {batch: speedup}}}`` and
    (optionally) writes the ``kvwrite/v1`` JSON trajectory."""
    from repro.core.tidestore.api import WriteOptions

    results: list[dict] = []
    speedups: dict = {}

    def record(mode, vs, bs, durability, dt, nops, scalar_dt):
        sp = scalar_dt / dt if dt > 0 else 0.0
        results.append({"mode": mode, "value_size": vs, "batch": bs,
                        "durability": durability,
                        "us_per_op": dt / nops * 1e6,
                        "ops_per_s": nops / dt,
                        "speedup_vs_scalar": sp})
        tag = f"kvwrite.v{vs}.{durability}.{mode}" + \
              (f".b{bs}" if bs > 1 else "")
        csv(f"{tag},{dt/nops*1e6:.2f},{nops/dt:.0f} ops/s"
            + (f" ({sp:.1f}x scalar)" if bs > 1 else ""))
        return sp

    from repro.core.tidestore.wal import _ENTRY_HDR, HEADER_SIZE

    from .engines import _tide_cfg
    seg_size = _tide_cfg().wal.segment_size

    configs = [(vs, "async", n_ops, None) for vs in value_sizes]
    if sync_probe:
        configs.append((1024, "sync", sync_ops,
                        WriteOptions(durability="sync")))
    for vs, durability, nops, opts in configs:
        keys = gen_keys(nops, seed=vs + (1 if durability == "sync" else 0))
        value = bytes(vs)
        scalar_dt = _time_scalar(factory, keys, value, opts)
        record("scalar", vs, 1, durability, scalar_dt, nops, scalar_dt)
        per_mode: dict = {"put_many": {}, "write_batch": {}}
        for bs in batch_sizes:
            if bs > nops:
                continue
            dt = _time_put_many(factory, keys, value, bs, opts)
            per_mode["put_many"][bs] = record("put_many", vs, bs, durability,
                                              dt, nops, scalar_dt)
            # write_batch is ONE atomic T_BATCH record, which cannot exceed
            # a segment — put_many has no such limit (records in a batch
            # are independent), a trajectory point worth keeping visible.
            body = HEADER_SIZE + bs * (HEADER_SIZE + _ENTRY_HDR.size
                                       + len(keys[0]) + vs)
            if body > seg_size:
                csv(f"kvwrite.v{vs}.{durability}.write_batch.b{bs},0,"
                    f"skipped (atomic batch of {body} B exceeds "
                    f"{seg_size} B segment; use put_many)")
                continue
            dt = _time_write_batch(factory, keys, value, bs, opts)
            per_mode["write_batch"][bs] = record("write_batch", vs, bs,
                                                 durability, dt, nops,
                                                 scalar_dt)
        speedups[(vs, durability)] = per_mode

    if json_path:
        with open(json_path, "w") as f:
            json.dump({"schema": "kvwrite/v1", "engine": "tidehunter",
                       "n_ops": n_ops, "results": results}, f, indent=1)
        csv(f"kvwrite.json,0,{json_path}")
    return speedups


def run_smoke(csv=print) -> bool:
    """CI sanity bound: batched write throughput must not lose to scalar.

    Tiny sizes, one batch size, no JSON — asserts speedup ≥ 1.0 (the real
    acceptance bar is ≥ 5×; this bound exists to catch pipeline
    regressions without becoming a flaky timing gate)."""
    speedups = run(n_ops=512, value_sizes=(128,), batch_sizes=(256,),
                   sync_probe=False, csv=csv, json_path=None)
    per_mode = speedups[(128, "async")]
    ok = all(sp >= 1.0 for mode in per_mode.values() for sp in mode.values())
    csv(f"kvwrite.smoke,0,{'ok' if ok else 'FAIL: batched < scalar'}")
    return ok


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run; exit 1 unless batched >= scalar")
    args = ap.parse_args()
    if args.smoke:
        sys.exit(0 if run_smoke() else 1)
    run()
