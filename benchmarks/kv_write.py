"""kvwrite suite: the vectorized write pipeline's throughput trajectory.

Measures scalar ``put`` vs ``put_many`` vs batched ``write_batch`` on a
fresh store per mode (insert workloads stay comparable), across value
sizes 128 B–16 KB and batch sizes, on the async-durability path, plus a
small sync-durability probe (where batching amortizes the fsync, not just
the allocation lock).  Acceptance bar: ``put_many``/``write_batch`` ≥ 5×
scalar ``put`` at batch ≥ 256 with 1 KB values, async durability.

A second sweep covers the parallel-copy write protocol (reserve → copy →
commit): large values {16 KB, 64 KB, 256 KB} × copy threads {1, 2, 4, 8}
capped at the host's core count (an oversubscribed copier count measures
scheduler thrash, not the engine, so such rows are never committed),
measured against the *staged* pre-parallel batched path (``b"".join`` +
one ``pwrite`` per run — the ``pwritev`` fallback shim, forced).  The
paper's claim (§3.1) is that atomic allocation + parallel copying
saturates the device at high writer counts; acceptance bar here: ≥ 2× the
staged path at 64 KB values with ≥ 4 copiers on multicore.

Emits ``BENCH_kvwrite.json`` so the write-perf trajectory records across
PRs.  Schema (``kvwrite/v2``)::

    {
      "schema": "kvwrite/v2",
      "engine": "tidehunter",
      "n_ops": 4096,
      "results": [
        {"mode": "scalar|put_many|write_batch",
         "value_size": 1024,            # bytes per value
         "batch": 256,                  # 1 for scalar
         "durability": "async|sync",
         "us_per_op": 12.3,
         "ops_per_s": 81000.0,
         "speedup_vs_scalar": 6.8},     # vs same (value_size, durability)
        {"mode": "put_many_staged|put_many",   # parallel-copy sweep
         "value_size": 65536,
         "batch": 128,
         "durability": "async",
         "copy_threads": 4,             # 0 = staged pre-parallel reference
         "us_per_op": 101.0,
         "ops_per_s": 9900.0,
         "speedup_vs_staged": 2.3},     # vs staged, same value_size
        ...
      ]
    }

``python -m benchmarks.kv_write --smoke`` runs a tiny configuration and
exits non-zero unless batched ≥ scalar throughput — a CI sanity bound on
the pipeline's shape, deliberately far below the 5× acceptance bar so it
never flakes on loaded runners.  ``--smoke-parallel`` is the parallel-copy
twin: best-of-3 at 64 KB values, parallel copiers must not lose to a
single copier; skips gracefully on single-core runners.
"""
from __future__ import annotations

import json
import os
import time

from .engines import Bench, gen_keys, make_tide

VALUE_SIZES = (128, 1024, 16384)
BATCH_SIZES = (64, 256, 1024)
PARALLEL_VALUE_SIZES = (16384, 65536, 262144)
COPY_THREAD_SWEEP = (1, 2, 4, 8)


def _host_copy_thread_sweep(sweep=COPY_THREAD_SWEEP) -> tuple:
    """The sweep capped at the host's core budget: a copier count beyond
    the cores measures scheduler thrash, not the protocol, and committing
    such rows makes the trajectory lie about the engine.  On a 1-core
    runner this leaves just ``(1,)`` (plus the staged ct0 reference)."""
    cores = os.cpu_count() or 1
    return tuple(ct for ct in sweep if ct <= cores) or (1,)


def _fresh(factory):
    return Bench("tidehunter", factory)


def _time_scalar(factory, keys, value, opts) -> float:
    b = _fresh(factory)
    t0 = time.perf_counter()
    if opts is None:
        for k in keys:
            b.db.put(k, value)
    else:
        for k in keys:
            b.db.put(k, value, opts=opts)
    dt = time.perf_counter() - t0
    b.close()
    return dt


def _time_put_many(factory, keys, value, bs, opts) -> float:
    b = _fresh(factory)
    t0 = time.perf_counter()
    for off in range(0, len(keys), bs):
        b.db.put_many([(k, value) for k in keys[off:off + bs]], opts=opts)
    dt = time.perf_counter() - t0
    b.close()
    return dt


def _time_put_many_ct(keys, value, bs, copy_threads, staged=False) -> float:
    """Time put_many on a fresh store with ``copy_threads`` copiers.

    ``staged=True`` reconstructs the pre-parallel-copy batched write path
    as the sweep's reference: the entry payload staged through one
    ``encode_entry`` concatenation, a single copier (serial CRC), and the
    pwritev fallback shim (``b"".join`` + one pwrite per run) — the exact
    cost structure the PR 3 pipeline had."""
    from repro.core.tidestore import wal as wal_mod
    from repro.core.tidestore.api import WriteOptions
    from repro.core.tidestore.db import TideDB
    from repro.core.tidestore.wal import encode_entry
    b = Bench("tidehunter",
              lambda p: make_tide(p, copy_threads=copy_threads))
    prev = wal_mod.HAVE_PWRITEV
    prev_parts = TideDB.__dict__["_entry_parts"]
    opts = None
    if staged:
        wal_mod.HAVE_PWRITEV = False
        TideDB._entry_parts = staticmethod(
            lambda ks_id, key, val, epoch: encode_entry(ks_id, key, val,
                                                        epoch))
        opts = WriteOptions(parallel_copy=False)
    try:
        t0 = time.perf_counter()
        for off in range(0, len(keys), bs):
            b.db.put_many([(k, value) for k in keys[off:off + bs]],
                          opts=opts)
        dt = time.perf_counter() - t0
    finally:
        wal_mod.HAVE_PWRITEV = prev
        TideDB._entry_parts = prev_parts
    b.close()
    return dt


def _time_write_batch(factory, keys, value, bs, opts) -> float:
    from repro.core.tidestore.api import WriteBatch
    b = _fresh(factory)
    t0 = time.perf_counter()
    for off in range(0, len(keys), bs):
        wb = WriteBatch()
        for k in keys[off:off + bs]:
            wb.put(k, value)
        b.db.write_batch(wb, opts=opts)
    dt = time.perf_counter() - t0
    b.close()
    return dt


def run_parallel(value_sizes=PARALLEL_VALUE_SIZES,
                 copy_threads=None,
                 batch_bytes: int = 16 << 20,
                 budget_bytes: int = 48 << 20, best_of: int = 1,
                 csv=print, results: list | None = None) -> dict:
    """Large-value parallel-copy sweep (§3.1 reserve → copy → commit):
    value size × copy-thread count, against the staged pre-parallel path.
    Batch size is held constant in *bytes* (``batch_bytes``), the regime
    the protocol targets: each ``put_many`` hands the copier pool several
    segment-sized runs to chop up.  ``copy_threads=None`` (the default)
    sweeps ``COPY_THREAD_SWEEP`` capped at the host's cores, so committed
    trajectories never contain oversubscribed configurations.  Returns
    ``{value_size: {copy_threads: speedup_vs_staged}}``; entries land in
    ``results`` (the ``kvwrite/v2`` trajectory) when given."""
    if copy_threads is None:
        copy_threads = _host_copy_thread_sweep()
    out: dict = {}

    def record(mode, vs, bs, ct, dt, nops, staged_dt):
        sp = staged_dt / dt if dt > 0 else 0.0
        if results is not None:
            results.append({"mode": mode, "value_size": vs, "batch": bs,
                            "durability": "async", "copy_threads": ct,
                            "us_per_op": dt / nops * 1e6,
                            "ops_per_s": nops / dt,
                            "speedup_vs_staged": sp})
        tag = f"kvwrite.v{vs}.async.{mode}.b{bs}" + \
              (f".ct{ct}" if ct else "")
        csv(f"{tag},{dt/nops*1e6:.2f},{nops/dt:.0f} ops/s"
            + (f" ({sp:.2f}x staged)" if ct else ""))
        return sp

    for vs in value_sizes:
        bs = max(16, batch_bytes // vs)
        nops = max(bs, (budget_bytes // vs) // bs * bs)
        keys = gen_keys(nops, seed=vs + 3)
        value = bytes(vs)
        staged_dt = min(_time_put_many_ct(keys, value, bs, 1, staged=True)
                        for _ in range(best_of))
        record("put_many_staged", vs, bs, 0, staged_dt, nops, staged_dt)
        out[vs] = {}
        for ct in copy_threads:
            dt = min(_time_put_many_ct(keys, value, bs, ct)
                     for _ in range(best_of))
            out[vs][ct] = record("put_many", vs, bs, ct, dt, nops, staged_dt)
    return out


def run(n_ops: int = 4096, value_sizes=VALUE_SIZES, batch_sizes=BATCH_SIZES,
        sync_probe: bool = True, sync_ops: int = 192, csv=print,
        json_path: str | None = "BENCH_kvwrite.json",
        factory=make_tide, parallel_sweep: bool = True) -> dict:
    """Returns ``{(value_size, durability): {mode: {batch: speedup}}}`` and
    (optionally) writes the ``kvwrite/v2`` JSON trajectory (including the
    parallel-copy sweep, keyed ``("parallel", value_size)``)."""
    from repro.core.tidestore.api import WriteOptions

    results: list[dict] = []
    speedups: dict = {}

    def record(mode, vs, bs, durability, dt, nops, scalar_dt):
        sp = scalar_dt / dt if dt > 0 else 0.0
        results.append({"mode": mode, "value_size": vs, "batch": bs,
                        "durability": durability,
                        "us_per_op": dt / nops * 1e6,
                        "ops_per_s": nops / dt,
                        "speedup_vs_scalar": sp})
        tag = f"kvwrite.v{vs}.{durability}.{mode}" + \
              (f".b{bs}" if bs > 1 else "")
        csv(f"{tag},{dt/nops*1e6:.2f},{nops/dt:.0f} ops/s"
            + (f" ({sp:.1f}x scalar)" if bs > 1 else ""))
        return sp

    from repro.core.tidestore.wal import _ENTRY_HDR, HEADER_SIZE

    from .engines import _tide_cfg
    seg_size = _tide_cfg().wal.segment_size

    configs = [(vs, "async", n_ops, None) for vs in value_sizes]
    if sync_probe:
        configs.append((1024, "sync", sync_ops,
                        WriteOptions(durability="sync")))
    for vs, durability, nops, opts in configs:
        keys = gen_keys(nops, seed=vs + (1 if durability == "sync" else 0))
        value = bytes(vs)
        scalar_dt = _time_scalar(factory, keys, value, opts)
        record("scalar", vs, 1, durability, scalar_dt, nops, scalar_dt)
        per_mode: dict = {"put_many": {}, "write_batch": {}}
        for bs in batch_sizes:
            if bs > nops:
                continue
            dt = _time_put_many(factory, keys, value, bs, opts)
            per_mode["put_many"][bs] = record("put_many", vs, bs, durability,
                                              dt, nops, scalar_dt)
            # write_batch is ONE atomic T_BATCH record, which cannot exceed
            # a segment — put_many has no such limit (records in a batch
            # are independent), a trajectory point worth keeping visible.
            body = HEADER_SIZE + bs * (HEADER_SIZE + _ENTRY_HDR.size
                                       + len(keys[0]) + vs)
            if body > seg_size:
                csv(f"kvwrite.v{vs}.{durability}.write_batch.b{bs},0,"
                    f"skipped (atomic batch of {body} B exceeds "
                    f"{seg_size} B segment; use put_many)")
                continue
            dt = _time_write_batch(factory, keys, value, bs, opts)
            per_mode["write_batch"][bs] = record("write_batch", vs, bs,
                                                 durability, dt, nops,
                                                 scalar_dt)
        speedups[(vs, durability)] = per_mode

    if parallel_sweep:
        for vs, per_ct in run_parallel(csv=csv, results=results,
                                       best_of=3).items():
            speedups[("parallel", vs)] = per_ct

    if json_path:
        with open(json_path, "w") as f:
            json.dump({"schema": "kvwrite/v2", "engine": "tidehunter",
                       "n_ops": n_ops, "results": results}, f, indent=1)
        csv(f"kvwrite.json,0,{json_path}")
    return speedups


def run_smoke(csv=print) -> bool:
    """CI sanity bound: batched write throughput must not lose to scalar.

    Tiny sizes, one batch size, no JSON — asserts speedup ≥ 1.0 (the real
    acceptance bar is ≥ 5×; this bound exists to catch pipeline
    regressions without becoming a flaky timing gate)."""
    speedups = run(n_ops=512, value_sizes=(128,), batch_sizes=(256,),
                   sync_probe=False, csv=csv, json_path=None,
                   parallel_sweep=False)
    per_mode = speedups[(128, "async")]
    ok = all(sp >= 1.0 for mode in per_mode.values() for sp in mode.values())
    csv(f"kvwrite.smoke,0,{'ok' if ok else 'FAIL: batched < scalar'}")
    return ok


def run_smoke_parallel(csv=print) -> bool:
    """CI sanity bound for the parallel-copy path: with ≥ 4 copiers,
    64 KB-value batched writes must not lose to a single copier
    (best-of-3; the real acceptance bar is ≥ 2× vs the *staged*
    pre-parallel path, checked by the full sweep).  Skips gracefully on
    single-core runners, where there is no parallelism to measure."""
    cores = os.cpu_count() or 1
    if cores < 2:
        csv("kvwrite.parallel.smoke,0,skipped (single-core runner)")
        return True
    # Cap copiers at the core count: on a 2-core runner, 4 copiers
    # oversubscribe and the parity bound would flake on a timing artifact
    # rather than a real regression.
    ct = min(4, cores)
    vs, bs, nops = 65536, 256, 512
    keys = gen_keys(nops, seed=99)
    value = bytes(vs)
    single = min(_time_put_many_ct(keys, value, bs, 1) for _ in range(3))
    para = min(_time_put_many_ct(keys, value, bs, ct) for _ in range(3))
    sp = single / para if para > 0 else 0.0
    ok = sp >= 1.0
    csv(f"kvwrite.parallel.smoke,0,"
        f"{'ok' if ok else 'FAIL: parallel < single-copier'} "
        f"({sp:.2f}x single-copier at {vs} B, ct={ct})")
    return ok


if __name__ == "__main__":
    import argparse
    import sys

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny run; exit 1 unless batched >= scalar")
    ap.add_argument("--smoke-parallel", action="store_true",
                    help="best-of-3 64KB probe; exit 1 unless parallel "
                         "copiers >= single copier (skips on 1 core)")
    args = ap.parse_args()
    if args.smoke or args.smoke_parallel:
        ok = True
        if args.smoke:
            ok = run_smoke() and ok
        if args.smoke_parallel:
            ok = run_smoke_parallel() and ok
        sys.exit(0 if ok else 1)
    run()
