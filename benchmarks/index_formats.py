"""Paper §6.3 / Figure 10: optimistic vs header index, window-size sweep.

Serialized index files are probed with (mostly negative) random lookups —
the paper's worst case for the optimistic format.  Reports lookups/s, mean
window iterations, and bytes read per lookup for each window size.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core.tidestore.index import (HeaderLookup, OptimisticLookup,
                                        serialize_header,
                                        serialize_optimistic)
from repro.core.tidestore.util import Metrics


def _make_index(n_entries: int, fmt: str, seed: int = 0):
    rng = np.random.default_rng(seed)
    keys = set()
    while len(keys) < n_entries:
        keys.update(rng.bytes(32) for _ in range(n_entries - len(keys)))
    entries = {k: i + 1 for i, k in enumerate(keys)}
    ser = serialize_optimistic if fmt == "optimistic" else serialize_header
    blob, count = ser(entries, 32)
    f = tempfile.NamedTemporaryFile(delete=False)
    f.write(blob)
    f.close()
    return f.name, count


def run(n_entries: int = 200_000, n_lookups: int = 3000, csv=print) -> None:
    rng = np.random.default_rng(42)
    queries = [rng.bytes(32) for _ in range(n_lookups)]

    for fmt in ("optimistic", "header"):
        path, count = _make_index(n_entries, fmt)
        fd = os.open(path, os.O_RDONLY)
        read_bytes = [0]

        def pread(off, n):
            data = os.pread(fd, n, off + (0 if fmt == "optimistic" else 0))
            read_bytes[0] += len(data)
            return data

        windows = (100, 200, 400, 800, 1600, 3200) if fmt == "optimistic" \
            else (800,)
        for w in windows:
            metrics = Metrics()
            if fmt == "optimistic":
                lk = OptimisticLookup(pread, count, 32, window_entries=w,
                                      metrics=metrics)
            else:
                lk = HeaderLookup(pread, count, 32, metrics=metrics)
            read_bytes[0] = 0
            t0 = time.perf_counter()
            hits = 0
            for q in queries:
                pos, _ = lk.lookup(q)
                hits += pos is not None
            dt = time.perf_counter() - t0
            iters = metrics.index_lookup_iterations / max(
                metrics.index_lookups, 1)
            csv(f"index.{fmt}.w{w}.lookups_per_s,"
                f"{dt/n_lookups*1e6:.2f},{n_lookups/dt:.0f}/s "
                f"iters={iters:.2f} bytes/lookup={read_bytes[0]//n_lookups}")
        os.close(fd)
        os.unlink(path)
