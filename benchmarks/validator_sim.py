"""Paper §6.4 stand-in: blockchain-validator workload.

Sustained transaction ingestion (hash-keyed ~1 KB objects, batched writes),
concurrent status/existence queries, and aggressive epoch pruning — the
combination that collapses compaction-based engines.  Reports sustained
tx/s, p50/p99 op latencies, disk write-amplification, and bytes reclaimed by
epoch pruning (zero-copy for tidehunter; whole-tree rewrite for the LSM).
"""
from __future__ import annotations

import hashlib
import time

import numpy as np

from repro.core.tidestore import DbConfig, KeyspaceConfig, TideDB
from repro.core.tidestore.wal import WalConfig

from .engines import ENGINES, Bench


def _validator_tide(path):
    # small segments so epoch expiry happens within the scaled run
    # (production segments are sized so an epoch spans many of them)
    return TideDB(path, DbConfig(
        keyspaces=[KeyspaceConfig("default", n_cells=256,
                                  dirty_flush_threshold=2048)],
        wal=WalConfig(segment_size=512 * 1024),
        index_wal=WalConfig(segment_size=32 * 1024 * 1024),
        cache_bytes=8 * 1024 * 1024,
    ))


def run(n_epochs: int = 6, tx_per_epoch: int = 1200, value_size: int = 1024,
        csv=print) -> None:
    engines = dict(ENGINES, **{"tidehunter": lambda p: _validator_tide(p)})
    for name, factory in engines.items():
        b = Bench(name, factory)
        v = bytes(value_size)
        lat = []
        t_start = time.perf_counter()
        total_tx = 0
        for epoch in range(n_epochs):
            for i in range(tx_per_epoch):
                key = hashlib.sha256(f"tx:{epoch}:{i}".encode()).digest()
                effects = key.ljust(value_size, b"\0")   # effects record
                t0 = time.perf_counter()
                if hasattr(b.db, "write_batch"):
                    b.db.write_batch(
                        [("put", 0, key, v),
                         ("put", 0, hashlib.sha256(key).digest(), effects)],
                        epoch=epoch)
                else:
                    b.db.put(key, v)
                    b.db.put(hashlib.sha256(key).digest(), effects)
                if i % 5 == 0:                        # concurrent reads
                    b.db.exists(hashlib.sha256(
                        f"tx:{epoch}:{i//2}".encode()).digest())
                lat.append(time.perf_counter() - t0)
                total_tx += 1
            # retire epochs older than 2 (validator pruning)
            if hasattr(b.db, "prune_epochs_below") and epoch >= 2:
                b.db.prune_epochs_below(epoch - 1)
        wall = time.perf_counter() - t_start
        lat_us = np.array(lat) * 1e6
        stats = b.db.stats() if hasattr(b.db, "stats") else {}
        wa = (stats.get("bytes_written_disk", 0)
              / max(stats.get("bytes_written_app", 1), 1))
        segs = stats.get("segments_deleted", 0)
        csv(f"validator.{name}.tx_per_s,{wall/total_tx*1e6:.2f},"
            f"{total_tx/wall:.0f} tx/s")
        csv(f"validator.{name}.p50_us,{np.percentile(lat_us, 50):.1f},"
            f"p99={np.percentile(lat_us, 99):.1f}us")
        csv(f"validator.{name}.write_amp,{wa:.2f},"
            f"segments_pruned={segs}")
        b.close()
