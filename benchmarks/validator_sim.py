"""Paper §6.4 stand-in: blockchain-validator workload.

Sustained transaction ingestion — hash-keyed ~1 KB effects objects, batched
through ``put_many`` with each batch tagged by its epoch — concurrent
existence queries, and aggressive epoch retirement.  Tidehunter runs with a
``PruneOptions(retain_epochs=2)`` policy driven the way ``KvBatchServer``
drives it: one bounded ``prune_step`` between ingest batches, so expired
epochs drop as whole segments *while transactions flow*.  The LSM baselines
have no epoch concept — retired state can only leave through compaction —
which is exactly the collapse the paper measures.

Reports per engine: sustained tx/s, p50/p99 ingest-batch latency, disk
write-amplification, segments reclaimed by epoch pruning, and a per-epoch
tx/s trajectory (``flatness`` = last-epoch tx/s / first-epoch tx/s; the
reproduction target is tidehunter staying ~flat while compaction engines
degrade as dead epochs pile up).
"""
from __future__ import annotations

import hashlib
import time

import numpy as np

from repro.core.tidestore import (DbConfig, KeyspaceConfig, PruneOptions,
                                  TideDB)
from repro.core.tidestore.wal import WalConfig

from .engines import ENGINES, Bench, multi_exists


def _validator_tide(path):
    # Small segments so epoch expiry happens within the scaled run
    # (production segments are sized so an epoch spans many of them).
    # retain_epochs=2 is the validator policy: epochs older than the two
    # newest are retired wholesale; the space-amp trigger stays lazy so
    # reclamation is almost entirely free segment drops, not copies.
    return TideDB(path, DbConfig(
        keyspaces=[KeyspaceConfig("default", n_cells=256,
                                  dirty_flush_threshold=2048)],
        wal=WalConfig(segment_size=256 * 1024),
        index_wal=WalConfig(segment_size=32 * 1024 * 1024),
        cache_bytes=8 * 1024 * 1024,
        prune=PruneOptions(retain_epochs=2, space_amp_trigger=3.0,
                           min_reclaim_bytes=1 * 1024 * 1024,
                           reclaim_fraction=0.25, batch_records=256),
    ))


def _tx_batch(epoch: int, lo: int, hi: int, value_size: int):
    """Transactions [lo, hi) of an epoch: digest key -> effects record."""
    out = []
    for i in range(lo, hi):
        key = hashlib.sha256(f"tx:{epoch}:{i}".encode()).digest()
        out.append((key, key.ljust(value_size, b"\0")))
    return out


def _ingest(db, items, epoch: int):
    """Batched ingest where the engine supports it; scalar loop otherwise —
    the same compat shape as ``multi_get``."""
    fn = getattr(db, "put_many", None)
    if fn is not None:
        fn(items, epoch=epoch)
    else:
        for k, v in items:
            db.put(k, v)


def run(n_epochs: int = 6, tx_per_epoch: int = 1200, value_size: int = 1024,
        batch: int = 128, csv=print) -> dict:
    engines = dict(ENGINES, **{"tidehunter": lambda p: _validator_tide(p)})
    report = {}
    for name, factory in engines.items():
        b = Bench(name, factory)
        db = b.db
        step = getattr(db, "prune_step", None)
        lat = []
        epoch_tx_s = []
        t_start = time.perf_counter()
        total_tx = 0
        for epoch in range(1, n_epochs + 1):
            t_ep = time.perf_counter()
            for lo in range(0, tx_per_epoch, batch):
                items = _tx_batch(epoch, lo, min(lo + batch, tx_per_epoch),
                                  value_size)
                t0 = time.perf_counter()
                _ingest(db, items, epoch)
                lat.append(time.perf_counter() - t0)
                total_tx += len(items)
                # concurrent status queries against the previous epoch
                multi_exists(db, [hashlib.sha256(
                    f"tx:{epoch - 1}:{lo + j}".encode()).digest()
                    for j in range(8)])
                if step is not None:
                    step()                      # serving-loop reclamation
            epoch_tx_s.append(tx_per_epoch
                              / (time.perf_counter() - t_ep))
        wall = time.perf_counter() - t_start
        lat_us = np.array(lat) * 1e6 / batch
        stats = db.stats() if hasattr(db, "stats") else {}
        wa = (stats.get("bytes_written_disk", 0)
              / max(stats.get("bytes_written_app", 1), 1))
        segs = (stats.get("segments_deleted", 0)
                + stats.get("segments_pruned", 0))
        flatness = epoch_tx_s[-1] / max(epoch_tx_s[0], 1e-9)
        csv(f"validator.{name}.tx_per_s,{wall/total_tx*1e6:.2f},"
            f"{total_tx/wall:.0f} tx/s")
        csv(f"validator.{name}.p50_us,{np.percentile(lat_us, 50):.1f},"
            f"p99={np.percentile(lat_us, 99):.1f}us per tx")
        csv(f"validator.{name}.write_amp,{wa:.2f},"
            f"segments_reclaimed={segs}")
        csv(f"validator.{name}.flatness,{flatness*100:.1f},"
            f"last/first epoch tx/s = {flatness:.2f}x")
        report[name] = {
            "tx_per_s": total_tx / wall,
            "p50_us": float(np.percentile(lat_us, 50)),
            "p99_us": float(np.percentile(lat_us, 99)),
            "write_amp": wa,
            "segments_reclaimed": segs,
            "epoch_tx_s": epoch_tx_s,
            "flatness": flatness,
        }
        b.close()
    return report


if __name__ == "__main__":
    run()
