"""Benchmark orchestrator — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Scaled to minutes on one
CPU core; ratios and curve shapes (not absolute ops/s) are the paper-
reproduction targets — see DESIGN.md §9.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: kv,kvbatch,kvshard,kvwrite,"
                         "kvexists,reloc,index,recovery,faults,overload,"
                         "system,validator,kernels,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (faults, index_formats, kernel_bench, kv_exists,
                   kv_throughput, kv_write, overload, recovery, relocation,
                   roofline_report, system_keyspace, validator_sim)

    suites = [
        ("kv", kv_throughput.run),          # Figures 1, 6, 7, 8
        ("kvbatch", kv_throughput.run_batched),  # batched read pipeline
        ("kvshard", kv_throughput.run_sharded),  # shard-parallel multi_get
        ("kvwrite", kv_write.run),          # vectorized write pipeline
        ("kvexists", kv_exists.run),        # fused existence-path probes
        ("reloc", relocation.run),          # Figure 9
        ("index", index_formats.run),       # Figure 10 / §6.3
        ("recovery", recovery.run),         # §3.3–3.4
        ("faults", faults.run),             # fault fuzz + scrub + degraded
        ("overload", overload.run),         # admission control loop
        ("system", system_keyspace.run),    # __system observation overhead
        ("validator", validator_sim.run),   # §6.4 (Sui stand-in)
        ("kernels", kernel_bench.run),      # Pallas kernels
        ("roofline", roofline_report.run),  # dry-run roofline table
    ]
    print("name,us_per_call,derived")
    for name, fn in suites:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            fn(csv=print)
        except Exception as e:  # pragma: no cover
            import traceback
            traceback.print_exc()
            print(f"{name}.ERROR,0,{e}")
        print(f"{name}.suite_wall_s,{(time.time()-t0)*1e6:.0f},"
              f"{time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
