"""Roofline table from the dry-run artifact (experiments/dryrun.json)."""
from __future__ import annotations

import json
import os


def run(path: str = "experiments/dryrun.json", csv=print) -> None:
    if not os.path.exists(path):
        csv("roofline.missing,0,run `python -m repro.launch.dryrun` first")
        return
    with open(path) as f:
        rows = json.load(f)
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        tag = f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}"
        if r.get("status") != "ok":
            csv(f"{tag},0,{r.get('status')}")
            continue
        rf = r["roofline"]
        csv(f"{tag},{rf['t_compute']*1e6:.0f},"
            f"t_mem={rf['t_memory']:.3f}s t_coll={rf['t_collective']:.3f}s "
            f"bottleneck={rf['bottleneck']} "
            f"mfu_bound={rf['roofline_fraction']*100:.1f}% "
            f"useful_ratio={rf['model_flops_ratio']:.2f}")
